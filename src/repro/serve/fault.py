"""Deterministic fault injection for the serving fleet (DESIGN.md §14).

Failures in the simulated cluster are *scheduled*, not sampled from wall
time: a :class:`FaultPlan` is a list of :class:`FaultEvent`\\ s pinned to
fleet steps, so a chaos run is exactly reproducible — the same plan against
the same traffic produces the same faults at the same points in the same
schedule, which is what lets the chaos harness assert bitwise-identical
surviving outputs against a no-fault control run.

Event kinds (``kind=arg@step`` in the spec grammar):

- ``kill_pe=4@6``    — PE 4 dies at step 6: its heap row becomes garbage,
  in-flight ops touching it cancel with error, and the owning pod's
  scheduler runs KV-block recovery (``serve/recovery.py``).
- ``kill_pod=pod1@6``— every PE of pod1 dies at once; the pod's live
  requests are adopted by surviving pods (full replay).
- ``partition=3@8``  — the inter-pod (dcn) fabric partitions at step 8 for
  3 steps: cross-pod traffic is neither delivered nor lost, it stays on
  the completion queue until the partition heals.
- ``drain=pod0@4``   — pod0 is administratively drained: the router stops
  placing new arrivals there, queued-but-unstarted requests re-route.
- ``join=pod0@9``    — a drained pod rejoins the router rotation.

Seeded *random* plans (:meth:`FaultPlan.random`) drive the property-test
sweep; the generator uses a counter-based PRNG keyed only by the seed, so
no wall clock or global RNG state leaks into the plan.

``ISHMEM_FAULT_PLAN`` / ``ISHMEM_FAULT_SEED`` expose the same knobs to the
launcher (``repro.launch.serve --chaos``).
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Mapping, Optional, Sequence

import jax.numpy as jnp

PREFIX = "ISHMEM_FAULT_"

#: recognized fault kinds, in spec-grammar order
KINDS = ("kill_pe", "kill_pod", "partition", "drain", "join")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: what happens, to whom, at which fleet step."""
    step: int
    kind: str                   # one of KINDS
    arg: str                    # pe id, pod name, or partition duration

    def spec(self) -> str:
        return f"{self.kind}={self.arg}@{self.step}"


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule (ordered by step, then spec text)."""
    events: tuple = ()
    seed: int = 0

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "FaultPlan":
        """Parse the comma-separated ``kind=arg@step`` grammar."""
        events: List[FaultEvent] = []
        for token in spec.split(","):
            token = token.strip()
            if not token:
                continue
            try:
                head, step_s = token.rsplit("@", 1)
                kind, arg = head.split("=", 1)
                step = int(step_s)
            except ValueError:
                raise ValueError(
                    f"fault spec token {token!r}: expected kind=arg@step "
                    f"(e.g. kill_pe=4@6)") from None
            kind = kind.strip().lower()
            if kind not in KINDS:
                raise ValueError(
                    f"fault spec token {token!r}: unknown kind {kind!r} "
                    f"(one of {KINDS})")
            if step < 0:
                raise ValueError(
                    f"fault spec token {token!r}: step must be >= 0")
            arg = arg.strip()
            if kind in ("kill_pe", "partition"):
                try:
                    if int(arg) < 0:
                        raise ValueError
                except ValueError:
                    raise ValueError(
                        f"fault spec token {token!r}: {kind} takes a "
                        f"non-negative integer, got {arg!r}") from None
            events.append(FaultEvent(step=step, kind=kind, arg=arg))
        events.sort(key=lambda e: (e.step, e.spec()))
        return cls(events=tuple(events), seed=seed)

    @classmethod
    def random(cls, seed: int, *, max_step: int,
               pes: Sequence[int] = (), pods: Sequence[str] = (),
               n_events: int = 1,
               partition_steps: int = 3) -> "FaultPlan":
        """Seeded random plan over the given victim sets — the chaos
        harness's sweep generator.  Counter-based PRNG (PCG64 keyed by the
        seed alone), so the plan is a pure function of its arguments."""
        import numpy as np
        rng = np.random.default_rng(np.random.PCG64((int(seed), 0xFA17)))
        kinds = []
        if pes:
            kinds.append("kill_pe")
        if pods:
            kinds += ["kill_pod", "partition"]
        if not kinds:
            raise ValueError("random plan needs pes and/or pods to target")
        events = []
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max(2, max_step)))
            if kind == "kill_pe":
                arg = str(pes[int(rng.integers(len(pes)))])
            elif kind == "kill_pod":
                arg = str(pods[int(rng.integers(len(pods)))])
            else:
                arg = str(partition_steps)
            events.append(FaultEvent(step=step, kind=kind, arg=arg))
        events.sort(key=lambda e: (e.step, e.spec()))
        return cls(events=tuple(events), seed=int(seed))

    def spec(self) -> str:
        """Round-trip back to the ``ISHMEM_FAULT_PLAN`` grammar."""
        return ",".join(e.spec() for e in self.events)


class FaultInjector:
    """Applies a :class:`FaultPlan` against a live Fleet, one step at a
    time.  The fleet calls :meth:`apply` at the top of every ``step()``
    (before arrivals submit), so a fault at step N happens-before step N's
    traffic — deterministically.  Partition healing is tracked here: a
    ``partition=K@N`` event downs the dcn fabric at N and heals it at
    N + K."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_step = {}
        for ev in plan.events:
            self._by_step.setdefault(ev.step, []).append(ev)
        self.heal_step: Optional[int] = None
        self.fired: List[dict] = []

    def apply(self, fleet, step: int) -> None:
        if self.heal_step is not None and step >= self.heal_step:
            fleet.heal()
            self.fired.append({"step": step, "kind": "heal", "arg": ""})
            self.heal_step = None
        for ev in self._by_step.get(step, ()):
            if ev.kind == "kill_pe":
                fleet.kill_pe(int(ev.arg))
            elif ev.kind == "kill_pod":
                fleet.kill_pod(ev.arg)
            elif ev.kind == "partition":
                fleet.partition()
                self.heal_step = step + int(ev.arg)
            elif ev.kind == "drain":
                fleet.drain(ev.arg)
            elif ev.kind == "join":
                fleet.join(ev.arg)
            self.fired.append({"step": step, "kind": ev.kind,
                               "arg": ev.arg})


# ---------------------------------------------------------------------------
# dead-row scrambling
# ---------------------------------------------------------------------------


def scramble_rows(heap, pes):
    """Overwrite the heap rows of dead PEs with poison (NaN for float
    pools, a large sentinel for integer pools).  A dead PE's memory is
    gone; anything that still silently reads it after recovery would
    propagate the poison into decoded tokens — which the chaos harness's
    bitwise-identity check then catches.  Returns the new heap."""
    for dt in list(heap.pools):
        pool = heap.pools[dt]
        dtype = jnp.dtype(dt)
        if jnp.issubdtype(dtype, jnp.floating):
            poison = jnp.asarray(jnp.nan, dtype)
        elif jnp.issubdtype(dtype, jnp.unsignedinteger):
            poison = jnp.asarray(jnp.iinfo(dtype).max, dtype)
        else:
            poison = jnp.asarray(jnp.iinfo(dtype).min + 1, dtype)
        for pe in pes:
            pool = pool.at[int(pe)].set(poison)
        heap = heap.replace_pool(dt, pool)
    return heap


# ---------------------------------------------------------------------------
# ISHMEM_FAULT_* environment knobs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEnvCfg:
    plan: str = ""              # kind=arg@step[,kind=arg@step...]
    seed: int = 0


def load_fault_env(environ: Optional[Mapping[str, str]] = None) -> FaultEnvCfg:
    """Parse ``ISHMEM_FAULT_PLAN`` / ``ISHMEM_FAULT_SEED`` (defaults on an
    empty env).  The plan string is validated here — a bad grammar fails
    at launch, not mid-chaos-run."""
    env = os.environ if environ is None else environ

    def get(name: str) -> Optional[str]:
        val = env.get(PREFIX + name)
        return val if val not in (None, "") else None

    seed_raw = get("SEED")
    if seed_raw is None:
        seed = 0
    else:
        try:
            seed = int(seed_raw)
        except ValueError:
            raise ValueError(f"{PREFIX}SEED: expected an integer, "
                             f"got {seed_raw!r}") from None
        if seed < 0:
            raise ValueError(f"{PREFIX}SEED: must be >= 0, got {seed}")
    plan = get("PLAN") or ""
    if plan:
        FaultPlan.parse(plan, seed=seed)        # validate the grammar now
    return FaultEnvCfg(plan=plan, seed=seed)
