"""KV-block recovery after a PE or pod failure (DESIGN.md §14).

The failure model is fail-stop: a dead PE's heap row is garbage (the fleet
poisons it — ``serve.fault.scramble_rows``) and every pending op touching it
cancels with an error (``CompletionQueue.cancel_pe``).  Recovery is pure
control plane over the *surviving* rows:

- **decode-PE death** (:func:`recover_decode_pe`) — every request whose
  decode destination died loses the resident KV copies on that row, but the
  staged payloads on the prefill *home* rows are pristine (decode writeback
  is a local store on the decode row only).  A victim **re-migrates** when
  its retained staged tail, a live home for every prompt block, and a live
  prefill source still exist; otherwise it **recomputes** from the prompt.
  Either way the tokens decoded before the fault become a *replay target*:
  decode re-derives them and ``DisaggScheduler._emit_token`` asserts each
  one equal instead of appending — the surviving stream stays
  bitwise-identical to the no-fault run (greedy decoding).

- **prefill-PE death** (:func:`recover_prefill_pe`) — the staged payloads
  themselves died.  Requests still depending on that row's bytes (waiting
  states with blocks homed there, or a parked prefill cache) recompute;
  DECODING/PREEMPTED requests survive untouched — their KV is already
  resident at a live decode PE.  Prefix-index entries homed on the casualty
  are dropped (entry-owned refs released, every surviving mapper's
  ``prefix_key`` cleared) so no future migration reads the poisoned row.

- **whole-pod death** (:func:`adopt_pod`) — the pod's live requests are
  *adopted*: each non-terminal record is fully released, marked RECOVERED
  (terminal on the dead pod), and re-submitted on a surviving pod with its
  decoded-so-far tokens as the new record's replay target.  Frontend
  placements re-point to the adopting pod, so ``Fleet.outputs()`` keeps
  serving every spec.

Ledger/auditor contract: every path here keeps the PR-8 invariants
machine-checkable mid-failure — slot words are reset only on live rows
(dead rows leave the audited set when the PE leaves ``decode_pes``),
``preemptions`` is cleared on recovered requests (the signal audit treats a
preempted request's slot word as re-armed), residency claims for dead PEs
are purged, and refcounts stay exact through entry drops because the entry
own-ref and each mapper's table refs are released by their owners.
"""
from __future__ import annotations

from repro.serve.scheduler import (DECODING, MIGRATING, PARKED, PREEMPTED,
                                   QUEUED, RECOVERED, RECOVERING, STAGED,
                                   STREAMING, TERMINAL)

#: waiting states whose KV still depends on prefill-side home rows — a dead
#: home forces these back through recompute (RECOVERING included so a second
#: fault mid-recovery re-classifies the victim instead of missing it)
_WAITING = (STAGED, STREAMING, PARKED, MIGRATING, RECOVERING)


# ---------------------------------------------------------------------------
# per-request teardown
# ---------------------------------------------------------------------------


def full_release(fleet, sched, req, heap):
    """Release every resource a request holds — block-table refs, COW
    reserves, prefix-entry ref, decode slot, stream signal, retained staged
    tail — resetting heap words only on live rows.  Refcount-exact: the
    auditors must pass immediately after.  Returns the new heap."""
    fault = fleet.ctx.fault
    pool, mig = sched.pool, sched.migrator
    pe, slot = req.decode_pe, req.slot
    if slot >= 0 and pe in sched.slot_req:
        view = sched.views.get(pe)
        if view is not None:
            sm = view.slots.get(slot)
            if sm is not None and sm.req_id == req.rid:
                # fold un-triggered COW reserves back for the release below
                req.cow_plan = {**view.detach_keep(slot), **req.cow_plan}
        if fault.alive(pe):
            heap = mig.reset_slot(heap, slot, pe)
            sched.banks[pe] = sched.engine.evict_slot(sched.banks[pe], slot)
        if sched.slot_req[pe][slot] == req.rid:
            sched.slot_req[pe][slot] = None
    if req.cow_plan:
        pool.release_ids(list(req.cow_plan.values()))
        req.cow_plan = {}
    pool.release(req.rid)
    if req.prefix_key is not None:
        entry = sched.prefix_index.get(req.prefix_key)
        if entry is not None:
            entry.refs -= 1
            if entry.refs <= 0:
                pool.release_ids(entry.block_ids)
                del sched.prefix_index[req.prefix_key]
        req.prefix_key = None
    req.shared_ids = []
    if req.park_sig >= 0:
        if fault.alive(req.decode_pe):
            heap = mig.reset_signal(
                heap, pool.stream_sig_ptr(req.park_sig), req.decode_pe)
        pool.free_stream_sig(req.park_sig)
        req.park_sig = -1
    mig.release_tail(req.rid)
    req.stream = None
    req.prefill_cache = None
    req.park_tail = None
    req.resume_pos = req.resume_tok = -1
    req.slot = -1
    req.decode_pe = -1
    req.prefill_pe = -1
    req.expected_sig = 0
    req.wire_blocks = 0
    req.fused_pending = 0
    req.first_block_step = -1
    req.preemptions = 0
    return heap


def _drop_waiting(sched, req) -> None:
    """Remove a victim from whichever scheduler container holds it."""
    for bag in (sched.streaming, sched.parked, sched.preempted,
                sched.migrating, sched.recovering, sched.staged,
                sched.queue):
        if req in bag:
            bag.remove(req)


def _mark_recovering(sched, req, step: int) -> None:
    """Park a victim for ``_phase_recover``: decoded-so-far tokens become
    the replay target and the recovery TTFD clock starts at ``step``."""
    req.replay_target = len(req.out)
    req.replayed = 0
    req.recoveries += 1
    req.recover_step = step
    req.state = RECOVERING
    sched.recovering.append(req)
    sched._trace_phase(req, "recovering",
                       end_args={"outcome": "fault"},
                       replay=req.replay_target)


# ---------------------------------------------------------------------------
# decode-PE death
# ---------------------------------------------------------------------------


def _can_remigrate(fleet, sched, req) -> bool:
    """A decode-death victim can re-send its staged KV iff every byte it
    needs still lives on a live row: the retained staged tail, a live home
    for every prompt block (a ``None`` home inside the prompt range means a
    fired COW whose only copy was the dead decode row), and a live prefill
    source PE for the tail/header sends.  Anything else recomputes."""
    if not sched.paged:
        return False                    # dense KV lived in the dead slot bank
    if not sched.migrator.has_tail(req.rid):
        return False
    if not fleet.ctx.fault.alive(req.prefill_pe):
        return False
    table = sched.pool.block_tables.get(req.rid)
    if not table:
        return False
    n_prompt = sched.pool.layout.blocks_for_prompt(req.prompt_len)
    for i, b in enumerate(table[:n_prompt]):
        home = sched.pool.home_of(b)
        if home is None or not fleet.ctx.fault.alive(home):
            return False
    return True


def recover_decode_pe(fleet, pod, pe: int, *, step: int) -> dict:
    """Retire a dead decode PE from its pod and recover every request whose
    decode destination it was.  Victims keep their block tables (and COW
    reserves) when re-migration is safe; otherwise they are fully released
    and recompute from the prompt.  Growth blocks are zeroed at re-attach
    and the replay rewrites every decode-position K/V, so the re-migrated
    stream is bitwise-identical (module docstring)."""
    sched = pod.sched
    heap = fleet.heap
    victims = [r for r in sched.requests.values()
               if r.decode_pe == pe
               and r.state in (STREAMING, PARKED, MIGRATING, DECODING,
                               PREEMPTED)]
    remigrated = recomputed = 0
    for req in victims:
        _drop_waiting(sched, req)
        view = sched.views.get(pe)
        if req.slot >= 0 and view is not None:
            sm = view.slots.get(req.slot)
            if sm is not None and sm.req_id == req.rid:
                req.cow_plan = {**view.detach_keep(req.slot), **req.cow_plan}
        if _can_remigrate(fleet, sched, req):
            # staged payloads + tail survive on live home rows: drop only
            # what was pinned to the dead row and let _phase_recover re-stage
            if req.park_sig >= 0:
                # the signal word lives on the dead row — no reset (the row
                # leaves the audited set); the id is safe to recycle because
                # a future stream targets a live row's word
                sched.pool.free_stream_sig(req.park_sig)
                req.park_sig = -1
            req.stream = None
            req.park_tail = None
            req.resume_pos = req.resume_tok = -1
            req.slot = -1
            req.decode_pe = -1
            req.expected_sig = 0
            req.wire_blocks = 0
            req.fused_pending = 0
            req.first_block_step = -1
            req.preemptions = 0
            remigrated += 1
        else:
            heap = full_release(fleet, sched, req, heap)
            recomputed += 1
        _mark_recovering(sched, req, step)
    sched.decode_pes.remove(pe)
    sched.banks.pop(pe, None)
    sched.slot_req.pop(pe, None)
    sched.views.pop(pe, None)
    for entry in sched.prefix_index.values():
        entry.resident.pop(pe, None)
    fleet.heap = heap
    return {"victims": len(victims), "remigrate": remigrated,
            "recompute": recomputed}


# ---------------------------------------------------------------------------
# prefill-PE death
# ---------------------------------------------------------------------------


def _sweep_dead_homes(fleet, dead_pes, *, step: int) -> int:
    """Cluster-wide sweep after prefill-side rows died: drop prefix-index
    entries whose payloads lived there, clear every surviving mapper's key,
    and recompute every waiting request whose table still depends on a dead
    home (the shared index spans pods, so victims can be anywhere).
    Returns the number of requests sent back through recovery."""
    dead = {int(p) for p in dead_pes}
    pool = fleet.pool
    doomed = [k for k, e in fleet.prefix_index.items()
              if e.home_pe in dead
              or any(pool.home_of(b) in dead for b in e.block_ids)]
    for k in doomed:
        entry = fleet.prefix_index.pop(k)
        pool.release_ids(entry.block_ids)
    if doomed:
        for pod in fleet.pods:
            for r in pod.sched.requests.values():
                if (r.prefix_key is not None
                        and r.prefix_key not in fleet.prefix_index):
                    r.prefix_key = None
                    r.shared_ids = []
    hit = 0
    for pod in fleet.pods:
        sched = pod.sched
        for r in list(sched.requests.values()):
            if r.state in _WAITING:
                table = pool.block_tables.get(r.rid) or []
                if (r.prefill_pe in dead
                        or any(pool.home_of(b) in dead for b in table)):
                    _drop_waiting(sched, r)
                    fleet.heap = full_release(fleet, sched, r, fleet.heap)
                    _mark_recovering(sched, r, step)
                    hit += 1
            elif (r.state == QUEUED and r.prefill_cache is not None
                    and r.prefill_pe in dead):
                # parked prefill result lived on the dead PE: re-run it
                r.prefill_cache = None
                r.prefill_pe = -1
    return hit


def recover_prefill_pe(fleet, pod, pe: int, *, step: int) -> dict:
    """Retire a dead prefill PE and recompute everything that still needed
    its row: staged payloads homed there (any pod — the prefix index is
    shared) and parked prefill caches.  DECODING/PREEMPTED requests ride
    through untouched: their KV is resident at a live decode PE."""
    pod.sched.prefill_pes.remove(pe)
    hit = _sweep_dead_homes(fleet, [pe], step=step)
    return {"victims": hit, "remigrate": 0, "recompute": hit}


# ---------------------------------------------------------------------------
# whole-pod adoption
# ---------------------------------------------------------------------------


def adopt_pod(fleet, dead_pod, *, step: int) -> int:
    """A whole pod died: surviving pods adopt its live requests.

    Every non-terminal record on the dead pod is fully released, marked
    RECOVERED (terminal — the adopted copy lives on under a new rid), and
    re-submitted on the least-loaded surviving pod with its original
    arrival time, SLO class, and decoded-so-far tokens as the new record's
    replay target.  Frontend placements re-point, so ``Fleet.outputs()``
    and the goodput report keep covering every spec.  Returns the number
    of requests adopted (shed-on-adoption rejections excluded)."""
    survivors = [p for p in fleet.pods if p is not dead_pod]
    if not survivors:
        raise RuntimeError(
            "whole-fleet failure: no surviving pod to adopt requests")
    dead_pes = [int(p) for p in dead_pod.team.pes()]
    sched = dead_pod.sched
    fleet.pods.remove(dead_pod)
    fleet.dead_pods.append(dead_pod)
    if dead_pod in fleet.router.pods:
        fleet.router.remove_pod(dead_pod)
    back = {(pn, rid): idx for idx, (pn, rid) in fleet.placements.items()}
    adopted = 0
    for old in list(sched.requests.values()):
        if old.state in TERMINAL:
            continue
        fleet.heap = full_release(fleet, sched, old, fleet.heap)
        old.state = RECOVERED
        old.finish_step = sched._step
        sched._trace_phase(old, None, end_args={"outcome": "recovered"})
        target = fleet.router._least_loaded()
        new_rid = target.sched.submit(
            old.batch, max_new=old.max_new, prefix_len=old.prefix_len,
            arrival_step=old.arrival_step, t_arrival=old.t_arrival,
            slo=old.slo)
        new = target.sched.requests[new_rid]
        if new.state not in TERMINAL:
            new.out = list(old.out)
            new.replay_target = len(old.out)
            new.replayed = 0
            new.recoveries = old.recoveries + 1
            new.recover_step = step
            adopted += 1
        idx = back.get((dead_pod.name, old.rid))
        if idx is not None:
            fleet.placements[idx] = (target.name, new_rid)
    # the dead scheduler never steps again: empty its live containers so
    # nothing aliases the adopted records (its request map stays for
    # report()/outputs() of pre-fault finishes)
    sched.queue.clear()
    sched.staged.clear()
    sched.streaming.clear()
    sched.parked.clear()
    sched.preempted.clear()
    sched.migrating.clear()
    sched.recovering.clear()
    # surviving pods may still map blocks homed on the dead pod's prefill
    # rows (shared prefixes travel cross-pod) — recompute those victims
    _sweep_dead_homes(fleet, dead_pes, step=step)
    for entry in fleet.prefix_index.values():
        for pe in dead_pes:
            entry.resident.pop(pe, None)
    return adopted
