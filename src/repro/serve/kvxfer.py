"""KV-cache migration engine: prefill PE -> decode PE over the SHMEM stack.

The hand-off protocol for one prefill (DESIGN.md §8, streamed form §9):

1. **stage** — the prefill PE packs the request's cache into pool blocks and
   writes them into *its own* row of the symmetric pool (local-tier stores;
   on real hardware the prefill attention kernel writes the paged pool
   directly, so staging is free).  Shared-prefix blocks another request
   already staged are skipped; growth blocks (pre-reserved for paged decode
   to write generated tokens into) are never staged — they carry no
   payload and never travel.
2. **migrate** — the request's staged blocks stream to the decode PE with
   ``put_signal_nbi``: block ids are sorted so heap-contiguous runs become
   queue-adjacent, every block in a run is a deferred nbi put read from the
   block's *home* row (the PE that staged it — shared blocks may live on a
   different prefill PE), and the run's last block carries a
   ``SIGNAL_ADD(run_len)`` flag update.  The completion engine
   write-combines each run into ONE wire transfer, and the cutover engine
   prices direct stores vs the copy engine on the *coalesced* size.  Blocks
   already resident at the destination (a shared prefix a previous request
   migrated there) are skipped entirely.  The tail (SSM states, ring
   positions, cross-KV) and the 4-word header follow, each signal-bearing.
   Cross-pod migrations (``dcn`` tier) route through the
   :class:`~repro.core.proxy.HostProxy` ring at flush.

   **Chunked streaming** (``open_stream``/``stream_chunk``/``stream_close``)
   is the same wire protocol cut across scheduler steps: each chunk of
   freshly filled blocks goes out mid-prefill with the same monotonically
   accumulating ``SIGNAL_ADD`` signal, and ``stream_flush`` drains the
   previous chunk's queue prefix while the next chunk's prefill compute
   runs — migration hides under prefill exactly as the paper's
   device-initiated pipelines hide communication inside kernels.

   Streams are *slot-less* while their blocks drain: ``open_stream`` may
   carry a pool *stream-signal* word (``KVPool.stream_sig_ptr``) instead of
   a decode slot's signal, so the streamed blocks park in the pool with no
   decode slot held.  The slot binds only at ``stream_close`` (set
   ``st.slot`` first), which sends just the tail + header — with one slot
   per decode PE the slot is occupied for the final two signal increments
   instead of the whole chunk drain (DESIGN.md §10).
3. **admit** — the decode PE polls ``signal_wait_until(sig, ">=", expected)``
   where ``expected = blocks_sent + 2`` (every wire block + tail + header).
   Queue order makes the signal the *last* update to land, so observing it
   proves every byte of the request is resident — no block is readable
   before its signal, property-tested against the pending-queue oracle in
   ``tests/test_disagg.py`` / ``tests/test_paged.py``.

Completion stays deferred until a completion point: the scheduler overlaps
migration under ongoing decode steps and only pays the flush when a slot is
actually admitted (or at an explicit ``flush``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp

from repro.core import cutover, device as device_mod, rma, \
    signal as signal_mod
from repro.core.heap import SymPtr
from repro.serve.kvpool import HEADER_WORDS, KVPool, pack_blocks, pack_tail

#: signal increments beyond the data blocks: one for the tail, one for the
#: header — the header's is the admission-visible "final block signal"
EXTRA_SIGNALS = 2


def expected_signal(n_blocks: int) -> int:
    return n_blocks + EXTRA_SIGNALS


def fused_admit_signal(n_wire: int) -> int:
    """Fused-protocol admission threshold: tail + header + the FIRST wire
    block (or just tail + header when nothing travels).  The remaining
    blocks are consumed per-signal by the decode-side device waits."""
    return EXTRA_SIGNALS + min(1, n_wire)


@dataclasses.dataclass
class MigrationReport:
    """What one request's migration put on the wire."""
    req_id: int
    slot: int
    src_pe: int
    dst_pe: int
    tier: str
    n_blocks: int               # staged (payload-bearing) blocks
    n_wire: int                 # blocks actually sent (skip-resident saves)
    n_runs: int                 # contiguous block runs (coalescing upper bound)
    bytes_paged: int            # wire bytes (skipped blocks excluded)
    bytes_tail: int
    bytes_skipped: int          # shared blocks already resident at dst
    expected_signal: int
    chunks: int = 1             # wire installments (1 = whole-prefill)
    bytes_dcn: int = 0          # wire bytes that crossed pods (proxy ring)
    fused: bool = False         # per-block signal protocol (migrate_fused)

    @property
    def bytes_total(self) -> int:
        return self.bytes_paged + self.bytes_tail + HEADER_WORDS * 4


@dataclasses.dataclass
class StreamState:
    """One in-flight chunked migration (prefill still 'computing').

    ``slot`` may be -1 while the stream is slot-less (parked): blocks
    accumulate against ``sig`` (a pool stream-signal word) and the slot is
    assigned only just before ``stream_close`` sends the tail + header.
    """
    req_id: int
    src_pe: int
    dst_pe: int
    slot: int
    prompt_len: int
    first_token: int
    pending: List[int]          # staged blocks not yet on the wire
    n_staged: int               # payload-bearing blocks (header n_blocks)
    n_skipped: int              # resident-at-dst blocks never sent
    sig: Optional[SymPtr] = None  # admission signal word (slot sig if None)
    sent: int = 0               # wire blocks issued so far (signal progress)
    chunks: int = 0
    runs: int = 0               # contiguous runs issued across all chunks
    final_wire: int = 0         # signal increments of the closing chunk
    bytes_dcn: int = 0          # cross-pod wire bytes so far

    @property
    def expected(self) -> int:
        """Admission threshold once the stream closes."""
        return self.sent + len(self.pending) + EXTRA_SIGNALS


def _contiguous_runs(ids: List[int]) -> List[List[int]]:
    runs: List[List[int]] = []
    for i in sorted(ids):
        if runs and i == runs[-1][-1] + 1:
            runs[-1].append(i)
        else:
            runs.append([i])
    return runs


class KVMigrator:
    """Streams paged KV blocks between PEs with signal-carried completion."""

    def __init__(self, ctx, pool: KVPool, *, proxy=None,
                 work_items: Optional[int] = None):
        self.ctx = ctx
        self.pool = pool
        self.proxy = proxy          # HostProxy for dcn-tier flushes (optional)
        # default to the configured work-group size (ISHMEM_WORK_GROUP_SIZE)
        # instead of a hardcoded width — satellite of the device-op PR
        self.work_items = (ctx.tuning.work_group_size
                          if work_items is None else work_items)
        self._staged_tails = {}     # req_id -> packed tail vector

    def _tracer(self):
        """Context tracer when recording, else None (guard hot paths)."""
        tr = getattr(self.ctx, "tracer", None)
        return tr if tr is not None and tr.enabled else None

    def _track(self, pe: int) -> tuple:
        """(pid, tid) trace track for a PE: its pod's process row."""
        return f"pod{self.ctx.node_of(pe)}", f"pe{pe}"

    # ------------------------------------------------------------- staging
    def stage(self, heap, req_id: int, cache, *, prompt_len: int,
              src_pe: int, batch_idx: int = 0, max_new: int = 0,
              shared_ids: Optional[List[int]] = None):
        """Allocate a finished prefill's block table and write the packed
        payloads into the prefill PE's own pool row.  Returns (heap, ids) or
        (heap, None) when the pool is exhausted (request stays queued).

        The table is laid out ``[shared prefix | private prompt | growth]``:
        ``shared_ids`` map another request's already-staged prefix blocks
        (incref'd, not re-packed); ``max_new > 0`` pre-reserves the growth
        blocks paged decode will write generated tokens into (zero payload,
        never migrated)."""
        lay = self.pool.layout
        shared_ids = list(shared_ids or [])
        n_prompt = lay.blocks_for_prompt(prompt_len)
        n_table = lay.blocks_for_decode(prompt_len, max_new)
        if shared_ids:
            ids = self.pool.alloc_with_prefix(req_id, shared_ids, n_table)
        else:
            ids = self.pool.alloc(req_id, n_table)
        if ids is None:
            return heap, None
        start = len(shared_ids)
        payloads = pack_blocks(lay, cache, batch_idx=batch_idx,
                               n_blocks=n_prompt - start, start=start)
        for bid, payload in zip(ids[start:n_prompt], payloads):
            heap = rma.put(self.ctx, heap, self.pool.block_ptr(bid), payload,
                           src_pe, src_pe=src_pe,
                           work_items=self.work_items)
        self.pool.set_home(ids[start:n_prompt], src_pe)
        self._staged_tails[req_id] = pack_tail(lay, cache,
                                               batch_idx=batch_idx)
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(src_pe)
            tr.instant("stage", "kvx", pid, tid, rid=req_id,
                       blocks=n_prompt - start, shared=len(shared_ids))
        return heap, ids

    def _wire_plan(self, req_id: int, skip) -> tuple:
        """(send_ids, n_staged, n_skipped): staged blocks to put on the wire
        — growth blocks have no home and never travel, ``skip`` holds shared
        blocks already resident at the destination."""
        ids = self.pool.blocks_of(req_id)
        staged = [i for i in ids if self.pool.home_of(i) is not None]
        send = [i for i in staged if i not in skip]
        return send, len(staged), len(staged) - len(send)

    # ----------------------------------------------------------- migration
    def _send_runs(self, heap, ids: List[int], sig, dst_pe: int) -> tuple:
        """Issue one signal-bearing deferred transfer per contiguous run;
        each block is read from its home row.  Returns
        (heap, n_runs, dcn_bytes) — the last is how many of the wire bytes
        crossed a pod boundary (shared-prefix blocks homed on another pod's
        prefill PE travel the host-proxy ring)."""
        runs = _contiguous_runs(ids)
        dcn = 0
        for run in runs:
            for bid in run[:-1]:
                ptr = self.pool.block_ptr(bid)
                home = self.pool.home_of(bid)
                heap = rma.put_nbi(self.ctx, heap, ptr,
                                   heap.read(ptr, home),
                                   dst_pe, src_pe=home,
                                   work_items=self.work_items)
                self._note_block(ptr.nbytes, home, dst_pe)
                if self.ctx.tier(home, dst_pe) == "dcn":
                    dcn += ptr.nbytes
            last = self.pool.block_ptr(run[-1])
            home = self.pool.home_of(run[-1])
            heap = signal_mod.put_signal_nbi(
                self.ctx, heap, last, heap.read(last, home), sig,
                len(run), signal_mod.SIGNAL_ADD, dst_pe, src_pe=home,
                work_items=self.work_items)
            self._note_block(last.nbytes, home, dst_pe)
            if self.ctx.tier(home, dst_pe) == "dcn":
                dcn += last.nbytes
        return heap, len(runs), dcn

    def _send_tail_header(self, heap, req_id: int, slot: int, src_pe: int,
                          dst_pe: int, prompt_len: int, first_token: int,
                          n_staged: int, sig=None):
        """Signal-bearing tail then header; the header's increment is the
        last queue entry, i.e. the admission threshold.  ``sig`` overrides
        the slot's signal word (parked streams ramp a pool stream signal)."""
        if sig is None:
            sig = self.pool.sig_ptr(slot)
        # LOOKED UP, not popped: the packed tail stays retained until the
        # request evicts (release_tail), so a decode-PE death after this
        # send can re-migrate the tail — the copy on the dead row is lost
        tail_vec = self._staged_tails[req_id]
        heap = signal_mod.put_signal_nbi(
            self.ctx, heap, self.pool.tail_ptr(slot), tail_vec, sig,
            1, signal_mod.SIGNAL_ADD, dst_pe, src_pe=src_pe,
            work_items=self.work_items)
        hdr = jnp.asarray([req_id, prompt_len, first_token, n_staged],
                          jnp.int32)
        heap = signal_mod.put_signal_nbi(
            self.ctx, heap, self.pool.header_ptr(slot), hdr, sig,
            1, signal_mod.SIGNAL_ADD, dst_pe, src_pe=src_pe,
            work_items=self.work_items)
        return heap

    def migrate(self, heap, req_id: int, *, src_pe: int, dst_pe: int,
                slot: int, prompt_len: int, first_token: int,
                skip=frozenset()) -> tuple:
        """Stream one staged request's blocks to ``dst_pe`` as deferred
        ``put_signal_nbi`` traffic — the whole-prefill (single-chunk) form.
        Nothing lands at the target until a completion point; returns
        ``(heap, MigrationReport)``."""
        lay = self.pool.layout
        send, n_staged, n_skipped = self._wire_plan(req_id, skip)
        tier = self.ctx.tier(src_pe, dst_pe)
        heap, n_runs, dcn = self._send_runs(heap, send,
                                            self.pool.sig_ptr(slot), dst_pe)
        heap = self._send_tail_header(heap, req_id, slot, src_pe, dst_pe,
                                      prompt_len, first_token, n_staged)
        if tier == "dcn":
            dcn += lay.tail_words * 4 + HEADER_WORDS * 4
        report = MigrationReport(
            req_id=req_id, slot=slot, src_pe=src_pe, dst_pe=dst_pe,
            tier=tier, n_blocks=n_staged, n_wire=len(send), n_runs=n_runs,
            bytes_paged=len(send) * lay.block_bytes,
            bytes_tail=lay.tail_words * 4,
            bytes_skipped=n_skipped * lay.block_bytes,
            expected_signal=expected_signal(len(send)), bytes_dcn=dcn)
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(src_pe)
            tr.instant("migrate", "kvx", pid, tid, rid=req_id,
                       dst_pe=dst_pe, tier=tier, runs=n_runs,
                       bytes=report.bytes_total, bytes_dcn=dcn)
            # flow arrow: issue here -> admit on the destination PE
            tr.flow_start(req_id, "migration", pid, tid)
        return heap, report

    # --------------------------------------------------- fused migration
    def migrate_fused(self, heap, req_id: int, *, src_pe: int, dst_pe: int,
                      slot: int, prompt_len: int, first_token: int,
                      skip=frozenset()) -> tuple:
        """Per-block-signal migration for the fused decode path.

        Wire order inverts :meth:`migrate`: the tail + header travel FIRST
        (each ``SIGNAL_ADD(1)``), then every wire block goes out
        INDIVIDUALLY with its own ``SIGNAL_ADD(1)``, in TABLE order, as a
        device work-group collaborative ``put_signal_nbi``.  No run
        coalescing — per-block signal granularity is the point: block k is
        provably resident once ``sig >= EXTRA_SIGNALS + k``, so the decode
        PE admits after the FIRST block signal
        (:func:`fused_admit_signal`) and consumes the rest as they land
        (``consume_blocks``), instead of stalling on the whole-request
        barrier ``sent + 2``.  Total signal increments are unchanged
        (``n_wire + 2``).  The honest trade: per-block sends forfeit the
        barrier protocol's write-combined runs."""
        lay = self.pool.layout
        send, n_staged, n_skipped = self._wire_plan(req_id, skip)
        tier = self.ctx.tier(src_pe, dst_pe)
        sig = self.pool.sig_ptr(slot)
        heap = self._send_tail_header(heap, req_id, slot, src_pe, dst_pe,
                                      prompt_len, first_token, n_staged)
        dcn = lay.tail_words * 4 + HEADER_WORDS * 4 if tier == "dcn" else 0
        for bid in send:
            ptr = self.pool.block_ptr(bid)
            home = self.pool.home_of(bid)
            wg = device_mod.work_group(self.ctx, size=self.work_items,
                                       pe=home)
            heap = device_mod.put_signal_nbi(
                wg, heap, ptr, heap.read(ptr, home), sig, 1,
                signal_mod.SIGNAL_ADD, dst_pe)
            if self.ctx.tier(home, dst_pe) == "dcn":
                dcn += ptr.nbytes
        report = MigrationReport(
            req_id=req_id, slot=slot, src_pe=src_pe, dst_pe=dst_pe,
            tier=tier, n_blocks=n_staged, n_wire=len(send),
            n_runs=len(send),
            bytes_paged=len(send) * lay.block_bytes,
            bytes_tail=lay.tail_words * 4,
            bytes_skipped=n_skipped * lay.block_bytes,
            expected_signal=expected_signal(len(send)), bytes_dcn=dcn,
            fused=True)
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(src_pe)
            tr.instant("migrate_fused", "kvx", pid, tid, rid=req_id,
                       dst_pe=dst_pe, tier=tier, blocks=len(send),
                       bytes=report.bytes_total, bytes_dcn=dcn)
            tr.flow_start(req_id, "migration", pid, tid)
        return heap, report

    # ----------------------------------------------------- chunked streaming
    def open_stream(self, req_id: int, *, src_pe: int, dst_pe: int,
                    slot: int, prompt_len: int, first_token: int,
                    skip=frozenset(), sig_ptr=None) -> StreamState:
        """Begin a chunked migration of an already-staged request.  Pure
        control plane: the wire plan is computed, nothing is issued yet.
        Pass ``sig_ptr`` (a pool stream-signal word) with ``slot=-1`` for a
        slot-less parked stream; the slot binds before ``stream_close``."""
        send, n_staged, n_skipped = self._wire_plan(req_id, skip)
        if sig_ptr is None:
            sig_ptr = self.pool.sig_ptr(slot)
        return StreamState(req_id=req_id, src_pe=src_pe, dst_pe=dst_pe,
                           slot=slot, prompt_len=prompt_len,
                           first_token=first_token, pending=send,
                           n_staged=n_staged, n_skipped=n_skipped,
                           sig=sig_ptr)

    def stream_chunk(self, heap, st: StreamState, chunk_blocks: int):
        """Put the next ``chunk_blocks`` filled blocks on the wire as
        signal-bearing runs.  ``SIGNAL_ADD`` keeps the stream signal
        monotonically increasing across chunks, so the decode side watches
        one word ramp toward the admission threshold."""
        take, st.pending = (st.pending[:chunk_blocks],
                            st.pending[chunk_blocks:])
        heap, n_runs, dcn = self._send_runs(heap, take, st.sig, st.dst_pe)
        st.sent += len(take)
        st.runs += n_runs
        st.chunks += 1
        st.bytes_dcn += dcn
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(st.src_pe)
            tr.instant("stream_chunk", "kvx", pid, tid, rid=st.req_id,
                       chunk=st.chunks, blocks=len(take),
                       remaining=len(st.pending))
        return heap

    def stream_flush(self, heap, st: StreamState):
        """Drain the wire under the next chunk's prefill compute: complete
        exactly the queue prefix this stream's signal depends on (the chunks
        issued so far) — other requests' in-flight traffic stays deferred,
        and the modeled comm clock charges the chunk's transfer *before*
        prefill finishes, which is where streaming's TTFD win comes from."""
        return self.ctx.pending.flush_dependency(
            self.ctx, heap, st.sig, st.dst_pe, proxy=self.proxy)

    def stream_close(self, heap, st: StreamState) -> tuple:
        """Final installment: any remaining blocks, then tail + header.  The
        header's signal increment completes the admission threshold
        ``sent + 2``.  A parked stream must have its decode slot bound
        (``st.slot``) by now — the tail/header land in that slot's region
        while the signal keeps ramping on ``st.sig``.  Returns
        ``(heap, MigrationReport)``."""
        lay = self.pool.layout
        if st.slot < 0:
            raise ValueError("stream_close before a decode slot was bound")
        st.final_wire = len(st.pending) + EXTRA_SIGNALS
        if st.pending:
            heap = self.stream_chunk(heap, st, len(st.pending))
        heap = self._send_tail_header(heap, st.req_id, st.slot, st.src_pe,
                                      st.dst_pe, st.prompt_len,
                                      st.first_token, st.n_staged, sig=st.sig)
        if self.ctx.tier(st.src_pe, st.dst_pe) == "dcn":
            st.bytes_dcn += lay.tail_words * 4 + HEADER_WORDS * 4
        report = MigrationReport(
            req_id=st.req_id, slot=st.slot, src_pe=st.src_pe,
            dst_pe=st.dst_pe, tier=self.ctx.tier(st.src_pe, st.dst_pe),
            n_blocks=st.n_staged, n_wire=st.sent, n_runs=st.runs,
            bytes_paged=st.sent * lay.block_bytes,
            bytes_tail=lay.tail_words * 4,
            bytes_skipped=st.n_skipped * lay.block_bytes,
            expected_signal=expected_signal(st.sent),
            chunks=st.chunks, bytes_dcn=st.bytes_dcn)
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(st.src_pe)
            tr.instant("stream_close", "kvx", pid, tid, rid=st.req_id,
                       dst_pe=st.dst_pe, chunks=st.chunks,
                       bytes=report.bytes_total, bytes_dcn=st.bytes_dcn)
            tr.flow_start(st.req_id, "migration", pid, tid)
        return heap, report

    def _note_block(self, nbytes: int, src_pe: int, dst_pe: int) -> None:
        """Per-block cutover telemetry: record the path (and standalone
        price) the cutover engine would pick for this block size, so the
        tuner sees block-granular samples alongside the coalesced
        flush-time transfers.  These records are *advisory* — the bytes are
        charged for real when the flush prices the coalesced transfer — so
        consumers of the modeled comm clock must exclude the
        ``kvxfer_block`` buckets (see ``DisaggScheduler._comm_clock``)."""
        tier = self.ctx.tier(src_pe, dst_pe)
        if tier == "dcn":
            path = "proxy"
        else:
            path = cutover.choose_path(nbytes, work_items=self.work_items,
                                       tier=tier, hw=self.ctx.hw,
                                       tuning=self.ctx.tuning)
        self.ctx.record("kvxfer_block", nbytes, path, tier, self.work_items)

    # ---------------------------------------------------------- completion
    def flush(self, heap):
        """Explicit completion point (quiet); dcn-tier traffic drains through
        the host proxy ring when one is attached."""
        return rma.quiet(self.ctx, heap, proxy=self.proxy)

    def pending_ops(self) -> int:
        return len(self.ctx.pending)

    # ----------------------------------------------------------- admission
    def try_admit(self, heap, slot: int, dst_pe: int, expected: int, *,
                  sig_ptr=None):
        """Signal-gated admission: returns ``(heap, header|None)``.  The
        wait is the completion point — observing ``sig >= expected`` forces
        the queue prefix the signal depends on, which includes every data
        block of this request (data-before-flag).  ``sig_ptr`` overrides
        the slot signal for parked streams."""
        if sig_ptr is None:
            sig_ptr = self.pool.sig_ptr(slot)
        if self.proxy is not None:
            # cross-pod: complete ONLY the queue prefix this request's
            # signal depends on, through the host-proxy ring machinery —
            # other requests' in-flight migrations stay deferred (their wire
            # cost is not charged to this admission)
            heap = self.ctx.pending.flush_dependency(
                self.ctx, heap, sig_ptr, dst_pe, proxy=self.proxy)
        heap, _, ok = signal_mod.signal_wait_until(
            self.ctx, heap, sig_ptr, dst_pe, "ge", expected)
        if not bool(ok):
            return heap, None
        hdr = [int(v) for v in heap.read(self.pool.header_ptr(slot), dst_pe)]
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(dst_pe)
            tr.instant("admit", "kvx", pid, tid, rid=hdr[0], slot=slot,
                       expected_signal=expected)
            tr.flow_end(hdr[0], "migration", pid, tid)
        return heap, {"req_id": hdr[0], "prompt_len": hdr[1],
                      "first_token": hdr[2], "n_blocks": hdr[3]}

    def try_admit_fused(self, heap, slot: int, dst_pe: int, n_wire: int):
        """First-block admission for a ``migrate_fused`` hand-off: the
        decode-side work-group waits for ``fused_admit_signal(n_wire)`` —
        tail + header + the first block — via the MINIMAL-prefix device
        wait, so the modeled comm clock charges exactly one block of wire
        time instead of the whole request.  Returns
        ``(heap, header|None, blocks_resident)``."""
        sig_ptr = self.pool.sig_ptr(slot)
        if self.proxy is not None:
            # cross-pod wire traffic must drain through the host-proxy
            # ring; the ring drains whole — fused admission degrades to the
            # dependency flush there (no minimal-prefix win over dcn)
            heap = self.ctx.pending.flush_dependency(
                self.ctx, heap, sig_ptr, dst_pe, proxy=self.proxy)
        wg = device_mod.work_group(self.ctx, size=self.work_items, pe=dst_pe)
        heap, cur, ok = device_mod.signal_wait_until(
            wg, heap, sig_ptr, dst_pe, "ge", fused_admit_signal(n_wire))
        if not bool(ok):
            return heap, None, max(0, int(cur) - EXTRA_SIGNALS)
        hdr = [int(v) for v in heap.read(self.pool.header_ptr(slot), dst_pe)]
        tr = self._tracer()
        if tr is not None:
            pid, tid = self._track(dst_pe)
            tr.instant("admit_fused", "kvx", pid, tid, rid=hdr[0], slot=slot,
                       expected_signal=fused_admit_signal(n_wire),
                       resident=int(cur) - EXTRA_SIGNALS)
            tr.flow_end(hdr[0], "migration", pid, tid)
        return heap, {"req_id": hdr[0], "prompt_len": hdr[1],
                      "first_token": hdr[2], "n_blocks": hdr[3]}, \
            max(0, int(cur) - EXTRA_SIGNALS)

    def consume_blocks(self, heap, slot: int, dst_pe: int, have: int,
                       need: int, *, rid: Optional[int] = None):
        """Per-block device waits: block k of a fused migration is readable
        once ``sig >= EXTRA_SIGNALS + k``.  Waits blocks ``have+1 .. need``
        in order, each wait forcing only the minimal queue prefix that
        delivers that block — the fusion protocol's consume side.  Returns
        ``(heap, blocks_now_resident)``.  ``rid`` attributes the consumed
        batch to a request lifeline (the critical-path analyzer folds these
        instants into its device-wait record)."""
        sig_ptr = self.pool.sig_ptr(slot)
        wg = device_mod.work_group(self.ctx, size=self.work_items, pe=dst_pe)
        resident = have
        for k in range(have + 1, need + 1):
            heap, _, ok = device_mod.signal_wait_until(
                wg, heap, sig_ptr, dst_pe, "ge", EXTRA_SIGNALS + k)
            if not bool(ok):
                break
            resident = k
        tr = self._tracer()
        if tr is not None and rid is not None and resident > have:
            pid, tid = self._track(dst_pe)
            tr.instant("consume", "kvx", pid, tid, rid=rid,
                       blocks=resident - have, resident=resident)
        return heap, resident

    def gather_tail(self, heap, slot: int, pe: int):
        """Decode-side read of an admitted request's tail vector (paged
        decode needs only this — the paged K/V stays in the pool)."""
        return heap.read(self.pool.tail_ptr(slot), pe)

    def gather(self, heap, req_id: int, slot: int, pe: int):
        """Decode-side read of an admitted request's payloads from this PE's
        own pool row: (block payloads in token order, tail vector).  Only
        the dense-rehydrate fallback path uses the block half; paged decode
        consumes blocks in place via ``serve/paged_attn.py``."""
        ids = self.pool.blocks_of(req_id)
        payloads = [heap.read(self.pool.block_ptr(i), pe) for i in ids]
        tail = heap.read(self.pool.tail_ptr(slot), pe)
        return payloads, tail

    def release_tail(self, req_id: int) -> None:
        """Drop the retained staged-tail snapshot (request finished or its
        recovery recomputes from the prompt)."""
        self._staged_tails.pop(req_id, None)

    def has_tail(self, req_id: int) -> bool:
        return req_id in self._staged_tails

    def reset_slot(self, heap, slot: int, pe: int):
        """Re-arm a slot for its next request: zero the signal word (a local
        store on the decode PE)."""
        return self.reset_signal(heap, self.pool.sig_ptr(slot), pe)

    def reset_signal(self, heap, sig_ptr, pe: int):
        """Zero an arbitrary signal word (recycled parked-stream signals)."""
        return rma.p(self.ctx, heap, sig_ptr, 0, pe, src_pe=pe)
