"""KV-cache migration engine: prefill PE -> decode PE over the SHMEM stack.

The hand-off protocol for one finished prefill (DESIGN.md §8):

1. **stage** — the prefill PE packs the request's cache into pool blocks and
   writes them into *its own* row of the symmetric pool (local-tier stores;
   on real hardware the prefill attention kernel writes the paged pool
   directly, so staging is free).
2. **migrate** — the request's blocks stream to the decode PE with
   ``put_signal_nbi``: block ids are sorted so heap-contiguous runs become
   queue-adjacent, every block in a run is a deferred nbi put, and the run's
   last block carries a ``SIGNAL_ADD(run_len)`` flag update.  The completion
   engine write-combines each run into ONE wire transfer, and the cutover
   engine prices direct stores vs the copy engine on the *coalesced* size.
   The tail (SSM states, ring positions, cross-KV) and the 4-word header
   follow, each signal-bearing.  Cross-pod migrations (``dcn`` tier) route
   through the :class:`~repro.core.proxy.HostProxy` ring at flush.
3. **admit** — the decode PE polls ``signal_wait_until(sig, ">=", expected)``
   where ``expected = n_blocks + 2`` (every data block + tail + header).
   Queue order makes the signal the *last* update to land, so observing it
   proves every block of the request is resident — no block is readable
   before its signal, property-tested against the pending-queue oracle in
   ``tests/test_disagg.py``.

Completion stays deferred until a completion point: the scheduler overlaps
migration under ongoing decode steps and only pays the flush when a slot is
actually admitted (or at an explicit ``flush``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp

from repro.core import cutover, rma, signal as signal_mod
from repro.serve.kvpool import HEADER_WORDS, KVPool, pack_blocks, pack_tail

#: signal increments beyond the data blocks: one for the tail, one for the
#: header — the header's is the admission-visible "final block signal"
EXTRA_SIGNALS = 2


def expected_signal(n_blocks: int) -> int:
    return n_blocks + EXTRA_SIGNALS


@dataclasses.dataclass
class MigrationReport:
    """What one request's migration put on the wire."""
    req_id: int
    slot: int
    src_pe: int
    dst_pe: int
    tier: str
    n_blocks: int
    n_runs: int                 # contiguous block runs (coalescing upper bound)
    bytes_paged: int
    bytes_tail: int
    expected_signal: int

    @property
    def bytes_total(self) -> int:
        return self.bytes_paged + self.bytes_tail + HEADER_WORDS * 4


def _contiguous_runs(ids: List[int]) -> List[List[int]]:
    runs: List[List[int]] = []
    for i in sorted(ids):
        if runs and i == runs[-1][-1] + 1:
            runs[-1].append(i)
        else:
            runs.append([i])
    return runs


class KVMigrator:
    """Streams paged KV blocks between PEs with signal-carried completion."""

    def __init__(self, ctx, pool: KVPool, *, proxy=None,
                 work_items: int = 128):
        self.ctx = ctx
        self.pool = pool
        self.proxy = proxy          # HostProxy for dcn-tier flushes (optional)
        self.work_items = work_items
        self._staged_tails = {}     # req_id -> packed tail vector

    # ------------------------------------------------------------- staging
    def stage(self, heap, req_id: int, cache, *, prompt_len: int,
              src_pe: int, batch_idx: int = 0):
        """Allocate blocks for a finished prefill and write the packed
        payloads into the prefill PE's own pool row.  Returns (heap, ids) or
        (heap, None) when the pool is exhausted (request stays queued)."""
        lay = self.pool.layout
        n_blocks = lay.blocks_for_prompt(prompt_len)
        ids = self.pool.alloc(req_id, n_blocks)
        if ids is None:
            return heap, None
        payloads = pack_blocks(lay, cache, batch_idx=batch_idx,
                               n_blocks=n_blocks)
        for bid, payload in zip(ids, payloads):
            heap = rma.put(self.ctx, heap, self.pool.block_ptr(bid), payload,
                           src_pe, src_pe=src_pe,
                           work_items=self.work_items)
        self._staged_tails[req_id] = pack_tail(lay, cache,
                                               batch_idx=batch_idx)
        return heap, ids

    # ----------------------------------------------------------- migration
    def migrate(self, heap, req_id: int, *, src_pe: int, dst_pe: int,
                slot: int, prompt_len: int, first_token: int,
                ) -> tuple:
        """Stream one staged request's blocks to ``dst_pe`` as deferred
        ``put_signal_nbi`` traffic.  Nothing lands at the target until a
        completion point; returns ``(heap, MigrationReport)``."""
        lay = self.pool.layout
        ids = self.pool.blocks_of(req_id)
        tier = self.ctx.tier(src_pe, dst_pe)
        sig = self.pool.sig_ptr(slot)
        runs = _contiguous_runs(ids)
        for run in runs:
            for bid in run[:-1]:
                ptr = self.pool.block_ptr(bid)
                heap = rma.put_nbi(self.ctx, heap, ptr,
                                   heap.read(ptr, src_pe), dst_pe,
                                   src_pe=src_pe, work_items=self.work_items)
                self._note_block(ptr.nbytes, tier)
            last = self.pool.block_ptr(run[-1])
            heap = signal_mod.put_signal_nbi(
                self.ctx, heap, last, heap.read(last, src_pe), sig,
                len(run), signal_mod.SIGNAL_ADD, dst_pe, src_pe=src_pe,
                work_items=self.work_items)
            self._note_block(last.nbytes, tier)
        # tail (recurrent states / ring positions / cross-KV)
        tail_vec = self._staged_tails.pop(req_id)
        heap = signal_mod.put_signal_nbi(
            self.ctx, heap, self.pool.tail_ptr(slot), tail_vec, sig,
            1, signal_mod.SIGNAL_ADD, dst_pe, src_pe=src_pe,
            work_items=self.work_items)
        # header last: its signal increment is the admission threshold
        hdr = jnp.asarray([req_id, prompt_len, first_token, len(ids)],
                          jnp.int32)
        heap = signal_mod.put_signal_nbi(
            self.ctx, heap, self.pool.header_ptr(slot), hdr, sig,
            1, signal_mod.SIGNAL_ADD, dst_pe, src_pe=src_pe,
            work_items=self.work_items)
        report = MigrationReport(
            req_id=req_id, slot=slot, src_pe=src_pe, dst_pe=dst_pe,
            tier=tier, n_blocks=len(ids), n_runs=len(runs),
            bytes_paged=len(ids) * lay.block_bytes,
            bytes_tail=lay.tail_words * 4,
            expected_signal=expected_signal(len(ids)))
        return heap, report

    def _note_block(self, nbytes: int, tier: str) -> None:
        """Per-block cutover telemetry: record the path (and standalone
        price) the cutover engine would pick for this block size, so the
        tuner sees block-granular samples alongside the coalesced
        flush-time transfers.  These records are *advisory* — the bytes are
        charged for real when the flush prices the coalesced transfer — so
        consumers of the modeled comm clock must exclude the
        ``kvxfer_block`` buckets (see ``DisaggScheduler._comm_clock``)."""
        if tier == "dcn":
            path = "proxy"
        else:
            path = cutover.choose_path(nbytes, work_items=self.work_items,
                                       tier=tier, hw=self.ctx.hw,
                                       tuning=self.ctx.tuning)
        self.ctx.record("kvxfer_block", nbytes, path, tier, self.work_items)

    # ---------------------------------------------------------- completion
    def flush(self, heap):
        """Explicit completion point (quiet); dcn-tier traffic drains through
        the host proxy ring when one is attached."""
        return rma.quiet(self.ctx, heap, proxy=self.proxy)

    def pending_ops(self) -> int:
        return len(self.ctx.pending)

    # ----------------------------------------------------------- admission
    def try_admit(self, heap, slot: int, dst_pe: int, expected: int):
        """Signal-gated admission: returns ``(heap, header|None)``.  The
        wait is the completion point — observing ``sig >= expected`` forces
        the queue prefix the signal depends on, which includes every data
        block of this request (data-before-flag)."""
        if self.proxy is not None:
            # cross-pod: complete ONLY the queue prefix this slot's signal
            # depends on, through the host-proxy ring machinery — other
            # requests' in-flight migrations stay deferred (their wire cost
            # is not charged to this admission)
            dep = self.ctx.pending.pending_for(self.pool.sig_ptr(slot),
                                               dst_pe)
            if dep is not None:
                heap = self.ctx.pending.flush_prefix(self.ctx, heap, dep,
                                                     proxy=self.proxy)
        heap, _, ok = signal_mod.signal_wait_until(
            self.ctx, heap, self.pool.sig_ptr(slot), dst_pe, "ge", expected)
        if not bool(ok):
            return heap, None
        hdr = [int(v) for v in heap.read(self.pool.header_ptr(slot), dst_pe)]
        return heap, {"req_id": hdr[0], "prompt_len": hdr[1],
                      "first_token": hdr[2], "n_blocks": hdr[3]}

    def gather(self, heap, req_id: int, slot: int, pe: int):
        """Decode-side read of an admitted request's payloads from this PE's
        own pool row: (block payloads in token order, tail vector)."""
        ids = self.pool.blocks_of(req_id)
        payloads = [heap.read(self.pool.block_ptr(i), pe) for i in ids]
        tail = heap.read(self.pool.tail_ptr(slot), pe)
        return payloads, tail

    def reset_slot(self, heap, slot: int, pe: int):
        """Re-arm a slot for its next request: zero the signal word (a local
        store on the decode PE)."""
        return rma.p(self.ctx, heap, self.pool.sig_ptr(slot), 0, pe,
                     src_pe=pe)
