"""Paged KV-cache block pool on the symmetric heap.

The disaggregated serving subsystem stores every request's decode state in
fixed-size *blocks* carved out of one symmetric allocation, so a prefill PE
can hand a finished request to a decode PE with plain one-sided
``put_signal_nbi`` — the pool layout is identical on every PE (the
OpenSHMEM symmetric contract), which makes a block id a cluster-wide
address.

Layout (derived from the model config once per pool):

- **paged leaves** — the self-attention K/V tensors, whose token axis grows
  with the request.  They are split along that axis into blocks of
  ``block_tokens`` tokens; block *b* of a request holds the slice
  ``[b*T, (b+1)*T)`` of every paged leaf, flattened and concatenated in a
  fixed order (layer-major within the block).  A dense-cache request of
  prompt length S only needs ``ceil(S/T)`` blocks migrated; a ring cache
  (SWA window) always moves its full ``ceil(W/T)`` blocks since occupied
  slots wrap.
- **tail** — everything else (SSM/recurrent states, ring position arrays,
  cross/encoder KV): fixed-size per request, packed into one float32 vector
  per request slot.  Packing is *lossless*: float32 passes through, bf16
  upcasts exactly, int32 is bit-cast — so a migrated request decodes
  bitwise-identically.
- **header** — 4 int32 words per slot ``(req_id, prompt_len, first_token,
  n_blocks)``: the control-plane record the decode side reads after the
  admission signal lands.
- **signal** — one int32 word per slot, the ``signal_wait_until`` target of
  the migration protocol (see ``serve/kvxfer.py``).
- **stream signals** — a small region of per-*stream* signal words
  (``max_streams`` int32), so a chunked migration can ramp its signal while
  it is *parked*: streamed blocks land in the pool before any decode slot is
  bound, and the slot binds only at ``stream_close`` (DESIGN.md §10) — the
  slot-signal word stays free for whole-prefill migrations.

Block metadata (free list, ref counts, block tables) is host-side, exactly
like the heap's own allocation metadata — the paper's "memory management
APIs are host-only".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.heap import SymPtr, SymmetricHeap
from repro.models import kvcache

HEADER_WORDS = 4            # (req_id, prompt_len, first_token, n_blocks)


# ---------------------------------------------------------------------------
# Layout derivation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedLeaf:
    """One K or V tensor paged over its token axis.

    Cache leaves are stacked ``(reps, B, W, nkv, hd)``; a block slice of this
    leaf contributes ``reps * T * nkv * hd`` words to each block payload.
    """
    unit_idx: int            # index into cache["blocks"]
    key: str                 # "k" | "v"
    reps: int
    width: int               # W — cache slots along the token axis
    nkv: int
    hd: int

    @property
    def words_per_token(self) -> int:
        return self.reps * self.nkv * self.hd


@dataclasses.dataclass(frozen=True)
class TailLeaf:
    """One non-paged cache leaf, packed losslessly into the f32 tail vector."""
    unit_idx: int
    key: str
    shape: tuple             # per-request shape (reps, 1, ...)
    dtype: str
    words: int


@dataclasses.dataclass(frozen=True)
class KVLayout:
    """Block/tail geometry for one (cfg, max_len, block_tokens) triple."""
    block_tokens: int
    blocks_per_request: int          # ceil(W / block_tokens)
    block_words: int                 # words per block payload
    tail_words: int
    kv_dtype: str
    cache_width: int                 # W — paged-leaf token-axis length
    ring: bool
    paged: Tuple[PagedLeaf, ...]
    tail: Tuple[TailLeaf, ...]

    @property
    def block_bytes(self) -> int:
        return self.block_words * jnp.dtype(self.kv_dtype).itemsize

    def blocks_for_prompt(self, prompt_len: int) -> int:
        """Blocks that must migrate for a request of this prompt length.

        Dense caches fill slots [0, S) so only the prefix blocks carry data;
        ring caches wrap, so every block is live.
        """
        if self.ring:
            return self.blocks_per_request
        need = -(-min(prompt_len, self.cache_width) // self.block_tokens)
        return max(1, need)

    def blocks_for_decode(self, prompt_len: int, max_new: int) -> int:
        """Block-table length a request needs through its whole decode: the
        prompt blocks plus the *growth* blocks its generated tokens will be
        written into (paged decode writes each new K/V token straight into
        the pool).  Ring caches wrap in place, so no growth; dense writes
        past the cache width are dropped (the `.at[].set` OOB rule), so the
        table never exceeds ``blocks_per_request``.

        This is THE table-size formula: ``KVMigrator.stage`` allocates with
        it and the scheduler's free-headroom precheck uses it — keep both
        on this one definition."""
        if self.ring:
            return self.blocks_per_request
        # decode steps consume tokens out[0..max_new-2] — the final sampled
        # token is emitted but never fed back — so the last K/V write lands
        # at prompt_len + max_new - 2, not prompt_len + max_new - 1
        last = min(prompt_len + max(max_new - 1, 0), self.cache_width) - 1
        return max(self.blocks_for_prompt(prompt_len),
                   last // self.block_tokens + 1)


def build_layout(cfg, max_len: int, *, block_tokens: int = 16) -> KVLayout:
    """Walk the model's cache structure and classify every leaf."""
    struct = kvcache.cache_struct(cfg, 1, max_len)
    W = kvcache.self_cache_len(cfg, max_len)
    ring = kvcache.is_ring(cfg, max_len)
    block_tokens = min(block_tokens, W)
    paged: List[PagedLeaf] = []
    tail: List[TailLeaf] = []
    kv_dtype = None
    for ui, entry in enumerate(struct["blocks"]):
        for key in sorted(entry):
            leaf = entry[key]
            shape = tuple(int(s) for s in leaf.shape)
            dt = jnp.dtype(leaf.dtype).name
            # a paged leaf is a self-attention K/V ring/dense buffer: shape
            # (reps, 1, W, nkv, hd) with the token axis at position 2
            if key in ("k", "v") and len(shape) == 5 and shape[2] == W:
                paged.append(PagedLeaf(ui, key, shape[0], shape[2],
                                       shape[3], shape[4]))
                kv_dtype = dt if kv_dtype is None else kv_dtype
                if dt != kv_dtype:
                    raise ValueError("mixed paged dtypes unsupported")
            else:
                n = 1
                for s in shape:
                    n *= s
                if dt not in ("float32", "int32", "bfloat16"):
                    # exactly what _pack_leaf_f32 round-trips losslessly —
                    # fail at layout derivation, not mid-serving
                    raise ValueError(f"unpackable tail dtype {dt}")
                tail.append(TailLeaf(ui, key, shape, dt, n))
    if not paged and kv_dtype is None:
        kv_dtype = "float32"           # pure-SSM arch: tail-only migration
    nb = -(-W // block_tokens) if paged else 1
    block_words = sum(p.words_per_token for p in paged) * block_tokens
    tail_words = sum(t.words for t in tail)
    return KVLayout(block_tokens=block_tokens, blocks_per_request=nb,
                    block_words=max(1, block_words),
                    tail_words=max(1, tail_words), kv_dtype=kv_dtype,
                    cache_width=W, ring=ring,
                    paged=tuple(paged), tail=tuple(tail))


# ---------------------------------------------------------------------------
# Lossless tail packing
# ---------------------------------------------------------------------------


def _pack_leaf_f32(x) -> jnp.ndarray:
    x = jnp.asarray(x)
    if x.dtype == jnp.float32:
        return x.reshape(-1)
    if x.dtype == jnp.bfloat16:
        return x.astype(jnp.float32).reshape(-1)        # exact upcast
    if x.dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(x, jnp.float32).reshape(-1)
    raise ValueError(f"unpackable tail dtype {x.dtype}")


def _unpack_leaf_f32(flat, shape, dtype):
    flat = jnp.asarray(flat, jnp.float32).reshape(shape)
    dt = jnp.dtype(dtype)
    if dt == jnp.float32:
        return flat
    if dt == jnp.dtype(jnp.bfloat16):
        return flat.astype(jnp.bfloat16)                # exact downcast back
    if dt == jnp.int32:
        return jax.lax.bitcast_convert_type(flat, jnp.int32)
    raise ValueError(f"unpackable tail dtype {dtype}")


# ---------------------------------------------------------------------------
# Cache <-> block payload conversion (pure functions)
# ---------------------------------------------------------------------------


def pack_blocks(layout: KVLayout, cache, *, batch_idx: int = 0,
                n_blocks: Optional[int] = None,
                start: int = 0) -> List[jnp.ndarray]:
    """Slice one request out of a cache into block payloads (prefill side).

    Returns ``n_blocks`` flat ``(block_words,)`` arrays covering token
    blocks ``[start, start + n_blocks)`` — shared-prefix staging skips the
    blocks another request already staged by passing ``start``.
    """
    if n_blocks is None:
        n_blocks = layout.blocks_per_request - start
    T = layout.block_tokens
    payloads = []
    for b in range(start, start + n_blocks):
        parts = []
        for pl in layout.paged:
            leaf = cache["blocks"][pl.unit_idx][pl.key]
            sl = leaf[:, batch_idx, b * T:(b + 1) * T]      # (reps,T,nkv,hd)
            if sl.shape[1] < T:                             # ragged last block
                pad = T - sl.shape[1]
                sl = jnp.pad(sl, ((0, 0), (0, pad), (0, 0), (0, 0)))
            parts.append(sl.reshape(-1))
        if not parts:
            parts = [jnp.zeros((layout.block_words,), layout.kv_dtype)]
        payloads.append(jnp.concatenate(parts).astype(layout.kv_dtype))
    return payloads


def pack_tail(layout: KVLayout, cache, *, batch_idx: int = 0) -> jnp.ndarray:
    """Pack the non-paged remainder of one request into a f32 vector."""
    parts = []
    for tl in layout.tail:
        leaf = cache["blocks"][tl.unit_idx][tl.key]
        parts.append(_pack_leaf_f32(leaf[:, batch_idx:batch_idx + 1]))
    if not parts:
        parts = [jnp.zeros((layout.tail_words,), jnp.float32)]
    return jnp.concatenate(parts)


def insert_blocks(layout: KVLayout, cache, slot: int,
                  payloads: List[jnp.ndarray]):
    """Scatter migrated block payloads into slot ``slot`` of a batched decode
    cache (inverse of :func:`pack_blocks`).  Returns the new cache pytree."""
    T = layout.block_tokens
    cache = dict(cache)
    blocks = [dict(e) for e in cache["blocks"]]     # only blocks are mutated
    for b, payload in enumerate(payloads):
        payload = jnp.asarray(payload).reshape(-1)
        off = 0
        t0 = b * T
        for pl in layout.paged:
            n = pl.words_per_token * T
            sl = payload[off:off + n].reshape(pl.reps, T, pl.nkv, pl.hd)
            off += n
            width = min(T, pl.width - t0)
            if width <= 0:
                continue
            leaf = blocks[pl.unit_idx][pl.key]
            blocks[pl.unit_idx][pl.key] = leaf.at[
                :, slot, t0:t0 + width].set(
                    sl[:, :width].astype(leaf.dtype))
    cache["blocks"] = blocks
    return cache


def insert_tail(layout: KVLayout, cache, slot: int, tail_vec):
    """Scatter a migrated tail vector into slot ``slot`` (inverse of
    :func:`pack_tail`)."""
    tail_vec = jnp.asarray(tail_vec, jnp.float32).reshape(-1)
    cache = dict(cache)
    blocks = [dict(e) for e in cache["blocks"]]     # only blocks are mutated
    off = 0
    for tl in layout.tail:
        sl = _unpack_leaf_f32(tail_vec[off:off + tl.words], tl.shape,
                              tl.dtype)
        off += tl.words
        leaf = blocks[tl.unit_idx][tl.key]
        blocks[tl.unit_idx][tl.key] = leaf.at[:, slot:slot + 1].set(
            sl.astype(leaf.dtype))
    cache["blocks"] = blocks
    return cache


# ---------------------------------------------------------------------------
# The pool: symmetric allocation + host-side block accounting
# ---------------------------------------------------------------------------


class KVPool:
    """Ref-counted paged block pool over one symmetric heap allocation.

    Every PE sees the identical layout, so ``block_ptr(i)`` is valid at the
    prefill PE (staging writes), on the wire (one-sided puts), and at the
    decode PE (admission reads).
    """

    def __init__(self, heap: SymmetricHeap, layout: KVLayout, *,
                 num_blocks: int, max_slots: int, max_streams: int = 16):
        self.layout = layout
        self.num_blocks = num_blocks
        self.max_slots = max_slots
        self.max_streams = max_streams
        self.data = heap.calloc((num_blocks * layout.block_words,),
                                layout.kv_dtype)
        self.tails = heap.calloc((max_slots * layout.tail_words,), "float32")
        self.headers = heap.calloc((max_slots * HEADER_WORDS,), "int32")
        self.signals = heap.calloc((max_slots,), "int32")
        self.stream_sigs = heap.calloc((max(1, max_streams),), "int32")
        self._stream_free: List[int] = list(range(max_streams - 1, -1, -1))
        self._refcnt: List[int] = [0] * num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.block_tables: Dict[int, List[int]] = {}
        # block id -> PE whose heap row holds the staged payload (the wire
        # source for migrations; growth/COW blocks have no home — they are
        # written only by the decode PE and never travel)
        self._home: Dict[int, int] = {}

    @classmethod
    def create(cls, heap: SymmetricHeap, cfg, max_len: int, *,
               num_blocks: int, max_slots: int,
               block_tokens: int = 16, max_streams: int = 16) -> "KVPool":
        layout = build_layout(cfg, max_len, block_tokens=block_tokens)
        return cls(heap, layout, num_blocks=num_blocks, max_slots=max_slots,
                   max_streams=max_streams)

    # ---------------------------------------------------------- addressing
    def block_ptr(self, block_id: int) -> SymPtr:
        if not 0 <= block_id < self.num_blocks:
            raise IndexError(block_id)
        w = self.layout.block_words
        return SymPtr(self.layout.kv_dtype,
                      self.data.offset + block_id * w, (w,))

    def _check_slot(self, slot: int) -> int:
        if not 0 <= slot < self.max_slots:
            raise IndexError(f"slot {slot} outside pool of {self.max_slots}")
        return slot

    def tail_ptr(self, slot: int) -> SymPtr:
        w = self.layout.tail_words
        return SymPtr("float32",
                      self.tails.offset + self._check_slot(slot) * w, (w,))

    def header_ptr(self, slot: int) -> SymPtr:
        return SymPtr("int32",
                      self.headers.offset
                      + self._check_slot(slot) * HEADER_WORDS,
                      (HEADER_WORDS,))

    def sig_ptr(self, slot: int) -> SymPtr:
        return SymPtr("int32", self.signals.offset + self._check_slot(slot),
                      ())

    def stream_sig_ptr(self, stream_id: int) -> SymPtr:
        if not 0 <= stream_id < self.max_streams:
            raise IndexError(
                f"stream {stream_id} outside pool of {self.max_streams}")
        return SymPtr("int32", self.stream_sigs.offset + stream_id, ())

    def alloc_stream_sig(self) -> Optional[int]:
        """Reserve a parked-stream signal word, or None when every word is
        carried by an in-flight stream (caller keeps the request staged)."""
        return self._stream_free.pop() if self._stream_free else None

    def free_stream_sig(self, stream_id: int) -> None:
        if stream_id in self._stream_free:
            raise ValueError(f"double free of stream signal {stream_id}")
        self._stream_free.append(stream_id)

    # ---------------------------------------------------------- accounting
    def _alloc_free(self, n_blocks: int) -> Optional[List[int]]:
        """Pop ``n_blocks`` off the free list (refcount 1 each), or None.
        Pops from the tail of the LIFO list; sorted so contiguous ids
        (adjacent heap ranges) end up queue-adjacent for write combining."""
        if n_blocks < 0:
            raise ValueError(f"negative block count {n_blocks}")
        if n_blocks > len(self._free):
            return None
        if n_blocks == 0:
            return []
        ids = sorted(self._free[-n_blocks:])
        del self._free[-n_blocks:]
        for i in ids:
            self._refcnt[i] = 1
        return ids

    def alloc(self, req_id: int, n_blocks: int) -> Optional[List[int]]:
        """Reserve ``n_blocks`` blocks for a request (refcount 1 each).
        Returns the block ids in token-block order, or None when the pool
        cannot satisfy the request (caller keeps it queued)."""
        if req_id in self.block_tables:
            raise ValueError(f"request {req_id} already has blocks")
        ids = self._alloc_free(n_blocks)
        if ids is None:
            return None
        self.block_tables[req_id] = ids
        return ids

    def alloc_with_prefix(self, req_id: int, shared_ids: List[int],
                          n_total: int) -> Optional[List[int]]:
        """Shared-prefix table: map ``shared_ids`` (another request's prefix
        blocks, incref'd in place) and allocate the remaining
        ``n_total - len(shared_ids)`` fresh.  All-or-nothing: a failed fresh
        allocation takes no references."""
        if req_id in self.block_tables:
            raise ValueError(f"request {req_id} already has blocks")
        fresh = self._alloc_free(n_total - len(shared_ids))
        if fresh is None:
            return None
        self.incref(shared_ids)
        self.block_tables[req_id] = list(shared_ids) + fresh
        return self.block_tables[req_id]

    def reserve(self, n_blocks: int) -> Optional[List[int]]:
        """Anonymous refcounted blocks outside any table — copy-on-write
        targets held by the paged decode view until first divergent write
        (then :meth:`remap` moves them into the table) or released unused
        via :meth:`release_ids` at eviction."""
        return self._alloc_free(n_blocks)

    def incref(self, block_ids: List[int]) -> None:
        """Shared-prefix reuse: another request references the same blocks."""
        for i in block_ids:
            if self._refcnt[i] <= 0:
                raise ValueError(f"incref on free block {i}")
            self._refcnt[i] += 1

    def _decref(self, i: int) -> int:
        self._refcnt[i] -= 1
        if self._refcnt[i] == 0:
            self._free.append(i)
            self._home.pop(i, None)
            return 1
        if self._refcnt[i] < 0:
            raise ValueError(f"double free of block {i}")
        return 0

    def release(self, req_id: int) -> int:
        """Drop a request's references; blocks whose refcount hits zero go
        back on the free list.  Returns the number of blocks freed."""
        ids = self.block_tables.pop(req_id, [])
        return sum(self._decref(i) for i in ids)

    def release_ids(self, block_ids: List[int]) -> int:
        """Drop one reference each on table-less blocks (unused COW
        reserves).  Returns the number freed."""
        return sum(self._decref(i) for i in block_ids)

    def remap(self, req_id: int, index: int, new_id: int) -> int:
        """Copy-on-write: swap table entry ``index`` to ``new_id`` (the
        caller transfers its reservation reference into the table) and drop
        this table's reference on the old, shared block.  Returns the old
        block id."""
        table = self.block_tables[req_id]
        old = table[index]
        table[index] = new_id
        self._decref(old)
        return old

    def blocks_of(self, req_id: int) -> List[int]:
        return list(self.block_tables[req_id])

    def refcount(self, block_id: int) -> int:
        return self._refcnt[block_id]

    def free_blocks(self) -> int:
        return len(self._free)

    # ----------------------------------------------------------- wire home
    def set_home(self, block_ids: List[int], pe: int) -> None:
        """Record which PE's row holds these blocks' staged payloads."""
        for i in block_ids:
            self._home[i] = pe

    def home_of(self, block_id: int) -> Optional[int]:
        return self._home.get(block_id)

    # ------------------------------------------------------------- metrics
    def stats(self, heap: Optional[SymmetricHeap] = None) -> dict:
        used = self.num_blocks - len(self._free)
        out = {
            "blocks_total": self.num_blocks,
            "blocks_in_use": used,
            "blocks_free": len(self._free),
            "block_bytes": self.layout.block_bytes,
            "bytes_in_use": used * self.layout.block_bytes,
            "utilization": used / self.num_blocks if self.num_blocks else 0.0,
            "requests_resident": len(self.block_tables),
            "blocks_shared": sum(1 for r in self._refcnt if r > 1),
            "streams_active": self.max_streams - len(self._stream_free),
        }
        if heap is not None:
            out["heap"] = heap.stats()
        return out
