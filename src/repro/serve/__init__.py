"""Serving layer: slot-based engine, paged KV pool, SHMEM-backed KV
migration, and the continuous-batching disaggregated scheduler."""
