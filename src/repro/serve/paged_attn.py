"""Paged decode attention: decode reads K/V straight from the block pool.

PR 3's migration engine parked a request's paged KV in the symmetric-heap
pool only long enough to rehydrate a dense per-slot cache (``kvxfer.gather``
+ ``kvpool.insert_blocks``); the decode step then ran against the dense
copy — a full-payload copy per admission and two live copies of every
resident request's KV.  This module removes the rehydrate: the decode PE's
pool row *is* the decode-side KV cache, indexed per slot through block
tables (DESIGN.md §9).

- **assemble** — one local load of the decode PE's pool row per step;
  each slot's block table gathers its payload rows and every paged leaf is
  rebuilt ``(reps, B, W, nkv, hd)`` exactly as ``insert_blocks`` would have
  built it, so the decode computation is bitwise-identical to the dense
  path (``tests/test_disagg.py`` / ``tests/test_paged.py``).  Table slots
  past a request's resident blocks read zero (the virgin dense-cache
  value); positions beyond the decode cursor are masked by the attention
  validity rules either way.
- **writeback** — the step's freshly projected K/V token lands back in the
  owning block: a local store on the decode PE, exactly the cache write a
  decode kernel performs, just targeting pool pages instead of a dense
  buffer.  Dense caches grow into blocks pre-reserved at staging time
  (admission is the backpressure point — decode never stalls mid-flight on
  the pool); ring caches wrap in place; writes past the cache width are
  dropped like the dense path's out-of-bounds scatter.
- **copy-on-write** — a slot whose table maps blocks shared with another
  request (the scheduler's shared-prefix policy) never writes them: the
  first divergent write copies the shared payload into the privately
  reserved block, remaps the table entry (``KVPool.remap``), and drops the
  shared reference.  Shared payload rows therefore stay pristine at every
  PE, which is what makes skip-resident migration sound.

Non-paged state (SSM/recurrent tails, ring ``kpos``, cross/encoder KV)
keeps living in the slot bank's batched cache — per-request, never shared.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import rma
from repro.serve.kvpool import KVPool


@dataclasses.dataclass
class _SlotMap:
    """Host-side per-slot decode state: which request, which COW targets."""
    req_id: int
    cow: Dict[int, int]          # table index -> reserved private block id


class PagedDecodeView:
    """Per-decode-PE window onto the pool: block tables + COW bookkeeping.

    The view is control-plane only (host-side, like all pool metadata); the
    data plane is the decode PE's own row of the symmetric pool, touched
    exclusively through local loads/stores here.
    """

    def __init__(self, pool: KVPool, pe: int, num_slots: int):
        self.pool = pool
        self.pe = pe
        self.num_slots = num_slots
        self.slots: Dict[int, _SlotMap] = {}
        self.cow_copies = 0

    # ------------------------------------------------------------ lifecycle
    def attach(self, heap, slot: int, req_id: int, *,
               fresh_ids: List[int], cow: Dict[int, int]):
        """Arm a slot at admission: install its table mapping and zero the
        never-migrated growth blocks on this PE's row, so an assembled leaf
        is byte-identical to the virgin dense cache it replaces.  ``cow``
        maps table indices that decode will write but whose blocks are
        shared, to their pre-reserved private targets."""
        self.slots[slot] = _SlotMap(req_id=req_id, cow=dict(cow))
        for bid in fresh_ids:
            ptr = self.pool.block_ptr(bid)
            heap = heap.write(ptr, self.pe,
                              jnp.zeros((ptr.size,), jnp.dtype(ptr.dtype)))
        return heap

    def detach(self, slot: int) -> int:
        """Disarm a finished slot; releases COW reservations that never
        triggered (table references are the scheduler's to release).
        Returns the number of reserve blocks freed back to the pool."""
        sm = self.slots.pop(slot, None)
        if sm is None:
            return 0
        return self.pool.release_ids(list(sm.cow.values()))

    def detach_keep(self, slot: int) -> Dict[int, int]:
        """Disarm a *preempted* slot WITHOUT releasing its un-triggered COW
        reservations — the request keeps decoding later, so the reserves
        (and their references) travel with it and re-arm at resume via
        ``attach(cow=...)``.  Returns that surviving cow map."""
        sm = self.slots.pop(slot, None)
        return {} if sm is None else dict(sm.cow)

    def table_of(self, slot: int) -> List[int]:
        return self.pool.blocks_of(self.slots[slot].req_id)

    # ------------------------------------------------------------- assemble
    def assemble(self, heap, cache):
        """Rebuild every paged leaf of the batched decode cache from the
        pool row through the slot block tables.  Returns a new cache pytree;
        non-paged leaves pass through from ``cache`` untouched."""
        lay = self.pool.layout
        if not lay.paged:
            return cache
        data = heap.read(self.pool.data, self.pe).reshape(
            self.pool.num_blocks, lay.block_words)
        # row num_blocks is the all-zeros page unmapped table slots read
        data = jnp.concatenate(
            [data, jnp.zeros((1, lay.block_words), data.dtype)], axis=0)
        nb = lay.blocks_per_request
        table = np.full((self.num_slots, nb), self.pool.num_blocks, np.int32)
        for s, sm in self.slots.items():
            ids = self.pool.blocks_of(sm.req_id)
            table[s, :len(ids)] = ids
        pay = data[jnp.asarray(table)]           # (B, nb, block_words)
        T = lay.block_tokens
        cache = dict(cache)
        blocks = [dict(e) for e in cache["blocks"]]
        off = 0
        for pl in lay.paged:
            n = pl.words_per_token * T
            leaf = pay[:, :, off:off + n].reshape(
                self.num_slots, nb, pl.reps, T, pl.nkv, pl.hd)
            off += n
            leaf = leaf.transpose(2, 0, 1, 3, 4, 5).reshape(
                pl.reps, self.num_slots, nb * T, pl.nkv, pl.hd)[:, :, :pl.width]
            ref = blocks[pl.unit_idx][pl.key]
            blocks[pl.unit_idx][pl.key] = leaf.astype(ref.dtype)
        cache["blocks"] = blocks
        return cache

    def strip(self, cache):
        """Zero the paged leaves of a post-step cache: the pool row is the
        single source of truth, and the slot bank must never re-grow a
        dense copy (asserted by the tests)."""
        lay = self.pool.layout
        if not lay.paged:
            return cache
        cache = dict(cache)
        blocks = [dict(e) for e in cache["blocks"]]
        for pl in lay.paged:
            blocks[pl.unit_idx][pl.key] = jnp.zeros_like(
                blocks[pl.unit_idx][pl.key])
        cache["blocks"] = blocks
        return cache

    # ------------------------------------------------------------ writeback
    def writeback(self, ctx, heap, new_cache, pos, active):
        """Store each active slot's just-written K/V token column into its
        owning pool block.  ``pos`` is the PRE-step cursor (the position the
        decode step wrote).  Copy-on-write fires here, before the first
        store into a shared block."""
        lay = self.pool.layout
        if not lay.paged:
            return heap
        T, W = lay.block_tokens, lay.cache_width
        pos = np.asarray(pos)
        for s in range(self.num_slots):
            if not active[s] or s not in self.slots:
                continue
            p = int(pos[s])
            idx = p % W if lay.ring else p
            if idx >= W:        # dense overrun: the scatter drops it
                continue
            b, t = idx // T, idx % T
            heap = self._cow(ctx, heap, s, b)
            bid = self.pool.blocks_of(self.slots[s].req_id)[b]
            ptr = self.pool.block_ptr(bid)
            payload = heap.read(ptr, self.pe)
            off = 0
            parts = []
            for pl in lay.paged:
                n = pl.words_per_token * T
                sl = payload[off:off + n].reshape(pl.reps, T, pl.nkv, pl.hd)
                col = new_cache["blocks"][pl.unit_idx][pl.key][:, s, idx]
                parts.append(sl.at[:, t].set(col.astype(sl.dtype))
                             .reshape(-1))
                off += n
            heap = heap.write(ptr, self.pe, jnp.concatenate(parts))
        return heap

    def _cow(self, ctx, heap, slot: int, b: int):
        """First divergent write into table index ``b``: copy the shared
        payload into the reserved private block (a local put on this PE,
        recorded on the ledger), remap the table, drop the shared ref."""
        sm = self.slots[slot]
        priv = sm.cow.pop(b, None)
        if priv is None:
            return heap
        src = self.pool.blocks_of(sm.req_id)[b]
        payload = heap.read(self.pool.block_ptr(src), self.pe)
        heap = rma.put(ctx, heap, self.pool.block_ptr(priv), payload,
                       self.pe, src_pe=self.pe)
        self.pool.remap(sm.req_id, b, priv)
        self.cow_copies += 1
        return heap
