"""Synthetic deterministic LM data pipeline.

Produces an endless stream of (tokens, labels) batches from a counter-seeded
PRNG — identical across hosts for a given (seed, step), sharded by slicing the
global batch, with a Zipf-ish marginal over the vocabulary so the loss curve
is non-trivial (uniform tokens give a flat CE at ln V).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_cdf(cfg: DataConfig):
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    w = ranks ** (-cfg.zipf_a)
    return np.cumsum(w / w.sum())


class TokenStream:
    """Deterministic, restartable, shardable token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._cdf = jnp.asarray(_zipf_cdf(cfg), jnp.float32)

    def batch(self, step: int, *, host_index: int = 0, num_hosts: int = 1):
        """Global batch for ``step``; slice [host_index] of num_hosts."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        per = cfg.global_batch // num_hosts
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        key = jax.random.fold_in(key, host_index)
        u = jax.random.uniform(key, (per, cfg.seq_len + 1))
        toks = jnp.searchsorted(self._cdf, u).astype(jnp.int32)
        toks = jnp.clip(toks, 0, cfg.vocab_size - 1)
        # order-2 structure: every even position repeats its left neighbor
        # with prob ~1/2 so next-token prediction is learnable
        idx = jnp.arange(cfg.seq_len + 1)
        toks = jnp.where((idx % 2 == 0) & (idx > 0),
                         jnp.roll(toks, 1, axis=1), toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def frontend(self, step: int, cfg_arch, batch_size: int):
        """Stubbed modality embeddings for audio/vlm archs (deterministic)."""
        key = jax.random.fold_in(jax.random.key(self.cfg.seed + 7), step)
        out = {}
        if cfg_arch.family == "audio":
            out["audio_embeds"] = jax.random.normal(
                key, (batch_size, cfg_arch.encoder_seq, cfg_arch.d_model),
                jnp.float32) * 0.1
        if cfg_arch.family == "vlm":
            out["image_embeds"] = jax.random.normal(
                key, (batch_size, cfg_arch.image_tokens, cfg_arch.d_model),
                jnp.float32) * 0.1
        return out
