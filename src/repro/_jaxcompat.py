"""Shared jax API compat shims (single copy for tests AND benchmarks).

The repo targets the current jax surface; older installs (0.4.x) spell some
APIs differently.  Shim only what is missing so new jax runs untouched.
Remaining known drift that cannot be shimmed (pallas interpret-mode remote
DMA under jit, ``Compiled.cost_analysis`` returning a list) is marked
per-test via ``tests/_drift.py`` — see ROADMAP.md "Open items".
"""
from __future__ import annotations


def ensure_jax_compat() -> None:
    import jax

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def _compat_shard_map(f, **kwargs):
            if "check_vma" in kwargs:             # renamed from check_rep
                kwargs["check_rep"] = kwargs.pop("check_vma")
            return _shard_map(f, **kwargs)

        jax.shard_map = _compat_shard_map
