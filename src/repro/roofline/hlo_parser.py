"""Static analyzer for optimized HLO text.

``compiled.cost_analysis()`` visits every while-loop body exactly once, so any
program with ``lax.scan`` (layer stacks, KV-block attention, SSM chunk scans)
under-reports FLOPs/bytes/collectives by the trip count.  This module parses
``compiled.as_text()`` and walks the call graph — scaling while bodies by
their ``known_trip_count`` (falling back to the loop-condition constant) — to
produce faithful totals:

  - ``flops``            : 2 * prod(output dims) * prod(contracting dims) per dot
  - ``bytes``            : HBM traffic model: every top-level materializing op
                           reads its operands and writes its output (fusions
                           count at the call site only)
  - ``collective_bytes`` : per-op wire bytes using ring-algorithm formulas
                           (all-reduce 2·s·(n-1)/n, all-gather/reduce-scatter/
                           all-to-all s·(n-1)/n, collective-permute s)

This is per-device arithmetic when run on an SPMD partitioned module (the
dry-run compiles with 256/512 devices, and the module text is the per-device
program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_instr_line(line: str):
    """Robust '  [ROOT] %name = TYPE opcode(rest' parser.

    Handles tuple types '(s32[], f32[2,3]{1,0}, ...)' whose commas/parens
    defeat a single regex.
    Returns (name, type_str, opcode, rest) or None.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    s = s[eq + 3:]
    if s.startswith("("):                 # tuple type: find matching paren
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = s[:i + 1]
                    s = s[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = s.find(" ")
        if sp < 0:
            return None
        type_str = s[:sp]
        s = s[sp + 1:].lstrip()
    par = s.find("(")
    if par < 0:
        return None
    opcode = s[:par]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, type_str, opcode, s[par + 1:]
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast",
               "all-reduce-start", "all-gather-start",
               "collective-permute-start", "ragged-all-to-all")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "broadcast", "reshape", "rng-bit-generator",
    "partition-id", "replica-id", "custom-call", "conditional", "while",
    "call", "domain", "token",
}


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str        # operands + attributes text


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def parse_computations(hlo_text: str) -> dict:
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and not line.startswith(" "):
            cur = Computation(mc.group(2), [], is_entry=bool(mc.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            cur.instrs.append(Instr(*parsed))
    return comps


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        # iota format [ngroups,gsize]
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        ids = m.group(1).strip("{}")
        return len([x for x in ids.split(",") if x.strip() != ""]) or default
    return default


def _wire_bytes(opcode: str, size: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if opcode.startswith("all-reduce"):
        return 2.0 * size * (n - 1) / n
    if opcode.startswith(("all-gather", "reduce-scatter", "all-to-all",
                          "ragged-all-to-all")):
        return size * (n - 1) / n
    return float(size)   # collective-permute / broadcast


class HloAnalysis:
    def __init__(self, hlo_text: str, num_partitions: int = 1):
        self.comps = parse_computations(hlo_text)
        self.num_partitions = num_partitions
        self.entry = next((c for c in self.comps.values() if c.is_entry), None)
        # name -> output type per computation (operand shape lookup)
        self._types = {}
        for c in self.comps.values():
            for ins in c.instrs:
                self._types[(c.name, ins.name)] = ins.type_str
        self.flops = 0.0
        self.bytes = 0.0
        self.transcendental = 0.0
        self.collectives = []            # (opcode, wire_bytes, mult)
        self.collective_bytes = 0.0
        self.dot_flops_by_comp = defaultdict(float)
        if self.entry is not None:
            self._walk(self.entry.name, 1.0, count_bytes=True)

    # ------------------------------------------------------------------
    def _operand_names(self, rest: str):
        rest = _CALLS_RE.sub("", rest)
        rest = _WHILE_BODY_RE.sub("", rest)
        rest = _WHILE_COND_RE.sub("", rest)
        rest = re.sub(r"to_apply=%?[\w.\-]+", "", rest)
        depth = 1
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return [m.group(1) for m in _OPERAND_RE.finditer(rest[:end])]

    def _fusion_param_read_bytes(self, callee: str):
        """Per-parameter effective read bytes inside a fusion: parameters
        consumed ONLY through dynamic-slice read the slice, not the whole
        operand (a scanned layer stack reads one layer per iteration)."""
        comp = self.comps.get(callee)
        if comp is None:
            return {}
        param_order = {}
        uses = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    param_order[ins.name] = int(m.group(1))
                continue
            for op_name in self._operand_names(ins.rest):
                if op_name in param_order:
                    uses.setdefault(op_name, []).append(ins)
        out = {}
        for pname, idx in param_order.items():
            insns = uses.get(pname, [])
            if insns and all(i.opcode == "dynamic-slice" for i in insns):
                out[idx] = sum(shape_bytes(i.type_str) for i in insns)
            elif insns and all(i.opcode == "dynamic-update-slice"
                               for i in insns):
                # in-place update target: traffic ~= the update, not the buffer
                upd = 0
                for i in insns:
                    ops = self._operand_names(i.rest)
                    if len(ops) > 1:
                        t = self._types.get((callee, ops[1]))
                        upd += shape_bytes(t) if t else 0
                out[idx] = upd
        return out

    def _operand_bytes(self, comp_name: str, rest: str) -> int:
        total = 0
        # operands appear before the first attribute comma group; just scan
        # %refs in the call parens region (attrs also contain %comp refs for
        # calls — acceptable overcount for called computations only, so strip
        # known patterns first)
        rest = _CALLS_RE.sub("", rest)
        rest = _WHILE_BODY_RE.sub("", rest)
        rest = _WHILE_COND_RE.sub("", rest)
        rest = re.sub(r"to_apply=%?[\w.\-]+", "", rest)
        # only the operand list (up to the closing paren at depth 0)
        depth = 1
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        for m in _OPERAND_RE.finditer(rest[:end]):
            t = self._types.get((comp_name, m.group(1)))
            if t:
                total += shape_bytes(t)
        return total

    def _dot_flops(self, comp_name: str, ins: Instr) -> float:
        out_elems = 1
        for d in shape_dims(ins.type_str):
            out_elems *= d
        # contraction size from lhs operand shape + lhs_contracting_dims
        mo = _OPERAND_RE.search(ins.rest)
        contract = 1
        if mo:
            lhs_t = self._types.get((comp_name, mo.group(1)), "")
            dims = shape_dims(lhs_t)
            mc = _CONTRACT_RE.search(ins.rest)
            if mc and mc.group(1):
                for ci in mc.group(1).split(","):
                    ci = int(ci)
                    if ci < len(dims):
                        contract *= dims[ci]
        return 2.0 * out_elems * contract

    def _walk(self, comp_name: str, mult: float, count_bytes: bool):
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                f = self._dot_flops(comp_name, ins) * mult
                self.flops += f
                self.dot_flops_by_comp[comp_name] += f
            elif op == "convolution":
                # not used by this framework; rough lower bound
                out = 1
                for d in shape_dims(ins.type_str):
                    out *= d
                self.flops += 2.0 * out * mult
            elif op in ("exponential", "tanh", "log", "rsqrt", "power",
                        "divide", "sine", "cosine", "logistic"):
                out = 1
                for d in shape_dims(ins.type_str):
                    out *= d
                self.transcendental += out * mult
            if op.rstrip("-start") in COLLECTIVES or op in COLLECTIVES:
                size = shape_bytes(ins.type_str)
                in_size = self._operand_bytes(comp_name, ins.rest)
                n = _group_size(ins.rest, self.num_partitions)
                wire = _wire_bytes(op, max(size, in_size), n)
                self.collectives.append((op, wire, mult))
                self.collective_bytes += wire * mult

            # ---- HBM traffic model ----
            if count_bytes and op not in _SKIP_BYTES_OPS:
                if op == "fusion":
                    mc = _CALLS_RE.search(ins.rest)
                    sliced = (self._fusion_param_read_bytes(mc.group(1))
                              if mc else {})
                    total = shape_bytes(ins.type_str)
                    for i, op_name in enumerate(self._operand_names(ins.rest)):
                        if i in sliced:
                            total += sliced[i]
                        else:
                            t = self._types.get((comp_name, op_name))
                            if t:
                                total += shape_bytes(t)
                    self.bytes += total * mult
                elif op == "dynamic-slice":
                    # reads the slice, not the whole operand
                    self.bytes += 2 * shape_bytes(ins.type_str) * mult
                else:
                    self.bytes += (shape_bytes(ins.type_str)
                                   + self._operand_bytes(comp_name, ins.rest)) \
                        * mult

            # ---- recursion ----
            if op == "while":
                trip = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                else:
                    mb = _WHILE_COND_RE.search(ins.rest)
                    if mb:
                        trip = self._cond_trip(mb.group(1)) or 1
                mb = _WHILE_BODY_RE.search(ins.rest)
                if mb:
                    self._walk(mb.group(1), mult * trip, count_bytes)
            elif op == "fusion":
                mc = _CALLS_RE.search(ins.rest)
                if mc:
                    # FLOPs inside fusions count; bytes were counted at call site
                    self._walk(mc.group(1), mult, count_bytes=False)
            elif op in ("call", "async-start"):
                mc = re.search(r"(?:to_apply|calls|called_computation)=%?([\w.\-]+)",
                               ins.rest)
                if mc:
                    self._walk(mc.group(1), mult, count_bytes)

    def _cond_trip(self, cond_name: str):
        comp = self.comps.get(cond_name)
        if comp is None:
            return None
        for ins in comp.instrs:
            if ins.opcode in ("compare", "fusion"):
                m = re.search(r"constant\((\d+)\)", ins.rest)
                if m:
                    return int(m.group(1))
        # constants may be named instructions
        consts = [ins for ins in comp.instrs if ins.opcode == "constant"]
        for ins in consts:
            m = re.search(r"constant\((\d+)\)", f"constant({ins.rest}")
            if m:
                return int(m.group(1))
        return None

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        per_kind = defaultdict(float)
        for op, wire, mult in self.collectives:
            per_kind[op.replace("-start", "")] += wire * mult
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "transcendental": self.transcendental,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(per_kind),
            "n_collective_sites": len(self.collectives),
        }


def analyze(hlo_text: str, num_partitions: int = 1) -> dict:
    return HloAnalysis(hlo_text, num_partitions).summary()


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze(open(sys.argv[1]).read()), indent=2))
