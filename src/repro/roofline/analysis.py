"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_wire_bytes_per_device / ICI_link_bw
plus MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference) and the
useful-compute ratio MODEL_FLOPS / (chips · HLO_FLOPs_per_device).

All per-device quantities come from the HLO static analyzer (while bodies
scaled by trip count); the raw ``cost_analysis`` numbers are recorded in the
JSON artifacts for cross-checking.

  PYTHONPATH=src python -m repro.roofline.analysis experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


def load(dirpath: str, mesh: str = "pod1"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, f"*.{mesh}.json"))):
        recs.append(json.load(open(p)))
    return recs


def terms(rec: dict) -> dict:
    h = rec["hlo_parsed"]
    chips = rec["chips"]
    t_c = h["flops"] / PEAK_FLOPS
    t_m = h["bytes"] / HBM_BW
    t_x = h["collective_bytes"] / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    useful = rec["model_flops"] / max(1.0, h["flops"] * chips)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "useful_ratio": useful,
        "step_s": max(t_c, t_m, t_x),
        "mfu_bound": (rec["model_flops"] / chips / PEAK_FLOPS)
        / max(t_c, t_m, t_x, 1e-12),
    }


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(dirpath: str, mesh: str = "pod1") -> str:
    rows = ["| arch | shape | status | compute | memory | collective | "
            "dominant | MODEL/HLO flops | roofline-bound MFU |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in load(dirpath, mesh):
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | "
                        f"{rec.get('status', '?')} | — | — | — | — | — | — |")
            continue
        t = terms(rec)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | ok | "
            f"{_fmt_s(t['compute_s'])} | {_fmt_s(t['memory_s'])} | "
            f"{_fmt_s(t['collective_s'])} | **{t['dominant']}** | "
            f"{t['useful_ratio']:.2f} | {t['mfu_bound']:.1%} |")
    return "\n".join(rows)


def what_would_help(rec: dict) -> str:
    t = terms(rec)
    if t["dominant"] == "collective":
        return ("reduce wire bytes: fewer/larger fused collectives, "
                "reduce-scatter instead of all-reduce+slice, keep TP "
                "activations sharded between ops")
    if t["dominant"] == "memory":
        return ("cut HBM traffic: larger fusion blocks, bf16 intermediates, "
                "less remat recompute, bigger attention KV blocks")
    return ("raise MXU utilization: larger per-device matmul tiles "
            "(less model-parallel splitting of small dims), fewer "
            "low-arithmetic-intensity einsums")


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    for mesh in ("pod1", "pod2"):
        recs = load(d, mesh)
        if not recs:
            continue
        print(f"\n### Roofline — {mesh} "
              f"({'256' if mesh == 'pod1' else '512'} chips)\n")
        print(table(d, mesh))


if __name__ == "__main__":
    main()
