"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,          # GQA
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    attention="full",
    mlp_type="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2407.14679 (Minitron: compact LMs via pruning+distillation)",
)
