"""Snowflake Arctic — 480B MoE: 128 experts top-2 + parallel dense residual MLP
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,          # GQA
    d_ff=4864,               # per-expert FFN
    vocab_size=32000,
    head_dim=128,
    attention="full",
    mlp_type="swiglu",
    num_experts=128,
    experts_per_token=2,     # top-2 routing
    moe_dense_ff=7168,       # dense residual MLP in parallel with the MoE
    rope_theta=10_000.0,
    optimizer="adafactor",   # 480B: AdamW fp32 state does not fit a v5e pod
    source="hf:Snowflake/snowflake-arctic-base (128e top-2 + dense residual)",
)
