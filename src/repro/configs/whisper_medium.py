"""Whisper-medium — encoder-decoder audio backbone; the mel+conv frontend is a
STUB supplying precomputed frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,           # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,         # MHA (GQA kv=16 == heads)
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=24,
    encoder_seq=1500,        # 30 s audio -> 1500 frame embeddings (conv stub)
    attention="full",
    mlp_type="gelu",
    source="arXiv:2212.04356 (Whisper; enc-dec, conv frontend stubbed)",
)
