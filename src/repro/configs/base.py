"""Architecture + input-shape configuration registry.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeSpec`` entries in ``SHAPES``.  ``input_specs`` builds
``jax.ShapeDtypeStruct`` stand-ins for the dry-run (no allocation), and
``reduced`` derives the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    source: str = ""                 # citation for the config

    # --- attention options -------------------------------------------------
    attention: str = "full"          # full | swa
    window: int = 4096               # SWA window (and long-context fallback window)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mlp_type: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_ff: int = 0            # arctic-style parallel dense-residual FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0               # Mamba2 state dim per head
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv: int = 4                # local conv width
    hybrid_period: int = 0           # zamba2: every Nth layer is the shared attn block
    xlstm_pattern: tuple = ()        # ("mlstm","slstm") repeating unit

    # --- enc-dec / vlm frontends (stubbed modality encoders) ----------------
    encoder_layers: int = 0          # whisper audio encoder depth
    encoder_seq: int = 1500          # whisper: #frame embeddings from conv stub
    cross_attn_every: int = 0        # vlm: 1 cross-attn layer per N layers
    image_tokens: int = 0            # vlm: #patch embeddings from ViT stub

    # --- numerics / training -----------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"         # adamw | adafactor (giant models)
    remat: bool = True

    # ------------------------------------------------------------------ utils
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context (skip rule for long_500k)?"""
        return self.family in ("ssm", "hybrid") or self.attention == "swa"

    @property
    def is_decoder(self) -> bool:
        return True  # all assigned archs have a decoder (whisper is enc-dec)

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    # -- parameter counting (for MODEL_FLOPS = 6 N D) -------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.hd
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        mlp_mult = 3 if self.mlp_type == "swiglu" else 2
        dense_mlp = mlp_mult * d * ff if ff else 0
        total = 0
        kinds = layer_kinds(self)
        shared_attn_counted = False
        for kind in kinds:
            if kind == "attn":
                total += attn + dense_mlp
            elif kind == "moe":
                e = self.experts_per_token if active_only else self.num_experts
                total += attn + e * mlp_mult * d * ff
                if self.moe_dense_ff:
                    total += mlp_mult * d * self.moe_dense_ff
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                total += 2 * d * d_in + d_in * d + d_in * self.ssm_conv
            elif kind == "mlstm":
                d_in = 2 * d
                total += 2 * d * d_in + d_in * d + 3 * d_in * hd  # qkv+gates approx
            elif kind == "slstm":
                total += 4 * d * d + 2 * d * (4 * d // 3)
            elif kind == "shared_attn":
                if not shared_attn_counted or not active_only:
                    pass
                if not shared_attn_counted:
                    total += attn + dense_mlp
                    shared_attn_counted = True
            elif kind == "cross":
                total += attn + dense_mlp  # cross-attn layer (kv from image embeds)
            elif kind == "encdec":
                total += 2 * attn + dense_mlp  # self-attn + cross-attn + mlp
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += self.encoder_layers * (attn + dense_mlp)
        return int(total)


def layer_kinds(cfg: ArchConfig) -> list:
    """Per-layer block kinds for the decoder stack."""
    if cfg.family == "moe":
        return ["moe"] * cfg.num_layers
    if cfg.family == "audio":
        return ["encdec"] * cfg.num_layers  # self-attn + cross-attn + mlp
    if cfg.family == "ssm" and cfg.xlstm_pattern:
        pat = list(cfg.xlstm_pattern)
        return [pat[i % len(pat)] for i in range(cfg.num_layers)]
    if cfg.family == "hybrid":
        per = cfg.hybrid_period or 6
        return ["shared_attn" if (i % per == per - 1) else "mamba"
                for i in range(cfg.num_layers)]
    if cfg.family == "vlm" and cfg.cross_attn_every:
        per = cfg.cross_attn_every
        return ["cross" if (i % per == per - 1) else "attn"
                for i in range(cfg.num_layers)]
    return ["attn"] * cfg.num_layers


def repeat_unit(cfg: ArchConfig):
    """(unit_kinds, n_repeats) such that unit*n == layer_kinds.

    The model scans over repeats of this unit to bound HLO size.
    """
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for ulen in range(1, n + 1):
        if n % ulen:
            continue
        unit = kinds[:ulen]
        if unit * (n // ulen) == kinds:
            return tuple(unit), n // ulen
    return tuple(kinds), 1


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input stand-ins (dry-run: no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def frontend_specs(cfg: ArchConfig, batch: int) -> dict:
    """Stubbed modality-frontend embeddings (the one allowed stub)."""
    out = {}
    if cfg.family == "audio":
        out["audio_embeds"] = _sds((batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        out["image_embeds"] = _sds((batch, cfg.image_tokens, cfg.d_model), cfg.dtype)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step kind."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        specs.update(frontend_specs(cfg, b))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((b, s), jnp.int32)}
        specs.update(frontend_specs(cfg, b))
        return specs
    # decode: ONE new token against a seq_len-deep cache.  Modality frontends
    # are consumed at prefill (their KV lives in the cache), not at decode.
    return {
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((b,), jnp.int32),
        "cache": cache_specs(cfg, b, s),
    }


def cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """Pytree of ShapeDtypeStructs matching models.kvcache.init_cache."""
    from repro.models import kvcache  # local import: keep configs jax-light

    return kvcache.cache_struct(cfg, batch, seq_len)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_NAMES = [
    "minitron_8b",
    "h2o_danube_3_4b",
    "starcoder2_7b",
    "llama4_scout_17b_a16e",
    "arctic_480b",
    "xlstm_125m",
    "whisper_medium",
    "zamba2_2_7b",
    "llama_3_2_vision_90b",
    "qwen3_4b",
]

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}


def get_config(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> dict:
    return {n: get_config(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants: same family, tiny dims
# ---------------------------------------------------------------------------


def reduced(cfg: ArchConfig) -> ArchConfig:
    """<=2-ish layers (one repeat unit), d_model<=512, <=4 experts, small vocab."""
    unit, _ = repeat_unit(cfg)
    layers = len(unit) if len(unit) > 1 else 2
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    d_model = 256
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=0 if cfg.d_ff == 0 else 512,
        vocab_size=512,
        window=64,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 32),
        image_tokens=min(cfg.image_tokens, 16) if cfg.image_tokens else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=(min(cfg.experts_per_token, 2)
                           if cfg.experts_per_token else 0),
        moe_dense_ff=256 if cfg.moe_dense_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
    return dataclasses.replace(cfg, **changes)
