"""Zamba2-2.7B — hybrid Mamba2 backbone with a shared attention block applied
periodically [arXiv:2411.15242]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,         # shared attn block is MHA
    d_ff=10240,              # MLP inside the shared attention block
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,            # Mamba2 state per head
    ssm_expand=2,
    hybrid_period=6,         # every 6th layer = the (weight-shared) attn block
    attention="full",        # windowed at 500k context (see DESIGN.md)
    window=4096,
    mlp_type="swiglu",
    source="arXiv:2411.15242 (Zamba2: Mamba2 + shared attention blocks)",
)
