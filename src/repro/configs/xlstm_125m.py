"""xLSTM-125M — alternating sLSTM + mLSTM blocks, no FFN (d_ff=0)
[arXiv:2405.04517]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                  # xLSTM blocks embed their own projections
    vocab_size=50304,
    head_dim=192,
    xlstm_pattern=("mlstm", "slstm"),
    source="arXiv:2405.04517 (xLSTM: sLSTM + mLSTM blocks)",
)
