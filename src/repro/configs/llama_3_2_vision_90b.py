"""Llama-3.2-Vision-90B backbone — decoder with interleaved cross-attention
image layers; ViT frontend is a STUB supplying patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,          # 80 self-attn + 20 cross-attn (every 5th)
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,          # GQA
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    cross_attn_every=5,
    image_tokens=1601,       # ViT stub output (1 tile of 1601 patch embeddings)
    attention="full",
    mlp_type="swiglu",
    rope_theta=500_000.0,
    optimizer="adafactor",   # 90B: AdamW fp32 state does not fit a v5e pod
    source="hf:meta-llama/Llama-3.2-11B-Vision (cross-attn image layers)",
)
