"""Llama-4-Scout-17B-16E — MoE decoder, 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,          # GQA
    d_ff=8192,               # per-expert FFN
    vocab_size=202048,
    head_dim=128,
    attention="full",
    mlp_type="swiglu",
    num_experts=16,
    experts_per_token=1,     # top-1 routing
    moe_dense_ff=8192,       # llama4 has a shared expert alongside routed ones
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (MoE 16e top-1, early fusion)",
)
