"""H2O-Danube3-4B — llama+mistral-style dense LM with sliding-window attention
[arXiv:2401.16818 (danube series)]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,          # GQA
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    attention="swa",         # mistral-style sliding window
    window=4096,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2401.16818 (H2O-Danube; llama/mistral mix, SWA)",
)
