"""StarCoder2-7B — code LM with GQA + RoPE [arXiv:2402.19173]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,          # GQA kv=4
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    attention="full",
    mlp_type="gelu",         # starcoder2 uses non-gated gelu MLP
    rope_theta=100_000.0,
    source="arXiv:2402.19173 (StarCoder2; GQA, RoPE)",
)
