"""Collective-ops facade consumed by model/training code inside shard_map.

Two interchangeable backends:

- ``xla``   : ``jax.lax`` collectives — the "copy-engine/GSPMD" path; the
              compiler schedules DMA-engine transfers.
- ``shmem`` : the paper's device-initiated path — Pallas ring kernels issuing
              remote DMAs from inside running kernels, with the cutover engine
              choosing push vs ring vs engine per message size (paper §IV).

Numerical equivalence between the backends is asserted by
tests/test_comms_equiv.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import cutover
from repro.kernels import ops as kops
from repro.tune import telemetry as telemetry_mod


def get_ops(backend: str, *, npes: int = None,
            hw: cutover.HwParams = cutover.HwParams(),
            tuning: cutover.Tuning = cutover.Tuning(),
            telemetry: telemetry_mod.Sink | None = None):
    if backend == "xla":
        return XlaOps()
    if backend == "shmem":
        assert npes is not None, "shmem backend needs the axis size"
        return ShmemOps(npes=npes, hw=hw, tuning=tuning, telemetry=telemetry)
    raise ValueError(backend)


class XlaOps:
    """Engine path: XLA-scheduled collectives."""

    name = "xla"

    def psum(self, x, axis_name):
        return jax.lax.psum(x, axis_name)

    def all_gather(self, x, axis_name):
        return jax.lax.all_gather(x, axis_name)

    def reduce_scatter(self, x, axis_name):
        # x: (npes, chunk...) addend rows -> (chunk...)
        return jax.lax.psum_scatter(x, axis_name, scatter_dimension=0,
                                    tiled=False)

    def broadcast(self, x, axis_name, root=0):
        src = jax.lax.all_gather(x, axis_name)
        return src[root]

    def ppermute(self, x, axis_name, perm):
        return jax.lax.ppermute(x, axis_name, perm)


@dataclasses.dataclass
class ShmemOps:
    """Device-initiated path with the paper's cutover policy."""

    npes: int
    hw: cutover.HwParams = cutover.HwParams()
    tuning: cutover.Tuning = cutover.Tuning()
    telemetry: telemetry_mod.Sink | None = None
    name: str = "shmem"

    # -- helpers -------------------------------------------------------------
    def _rows(self, x):
        """Flatten x to (npes, k) addend rows (pad to a multiple of npes*128)."""
        flat = x.reshape(-1)
        unit = self.npes * 128
        pad = (-flat.size) % unit
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(self.npes, -1), x.shape, pad

    def _choose(self, nbytes):
        """Per-collective transport pick: work-group context flows from the
        tuning (ISHMEM_WORK_GROUP_SIZE), learned tables via tuning.table."""
        return cutover.choose_path(nbytes, work_items=self.tuning.work_group_size,
                                   tier="ici", hw=self.hw, tuning=self.tuning)

    def _note(self, op, x, path=None):
        if self.telemetry is None:
            return
        nbytes = int(x.size * x.dtype.itemsize)
        if path is None:                   # only price the decision when a
            path = self._choose(nbytes)    # sink is actually listening
        wi = self.tuning.work_group_size
        priced_path = path if path in ("direct", "engine") else "direct"
        if op == "ppermute":               # one neighbor put, not a collective
            t = cutover.op_time(nbytes, priced_path, work_items=wi,
                                tier="ici", hw=self.hw)
        else:
            kind = "fcollect" if op in ("all_gather", "broadcast") else "reduce"
            t = cutover.t_collective(kind, nbytes, self.npes, work_items=wi,
                                     path=priced_path, hw=self.hw)
        self.telemetry.record(telemetry_mod.OpRecord(op, nbytes, path, "ici",
                                                     t, wi))

    def _note_overlap(self, op, x, *, overlap: bool):
        """Record the modeled cost of a ring allreduce under the nbi
        (overlapped) or blocking schedule — the completion-engine pricing of
        the same data movement (cutover.t_ring_allreduce)."""
        if self.telemetry is None:
            return
        nbytes = int(x.size * x.dtype.itemsize)
        wi = self.tuning.work_group_size
        t = cutover.t_ring_allreduce(nbytes, self.npes, work_items=wi,
                                     tier="ici", hw=self.hw,
                                     tuning=self.tuning, overlap=overlap)
        self.telemetry.record(telemetry_mod.OpRecord(op, nbytes, "direct",
                                                     "ici", t, wi))

    def modeled_overlap_efficiency(self, nbytes: int, *,
                                   step_compute_bytes: float = None) -> float:
        """Blocking-over-nbi modeled time ratio for one ring allreduce of
        ``nbytes``.  ``step_compute_bytes`` is the application tile compute
        each arriving chunk feeds (default: a consumer tile the size of four
        chunks — the next layer reading the chunk against resident weights);
        > 1.0 whenever that compute can hide under the in-flight transfer."""
        if step_compute_bytes is None:
            step_compute_bytes = 4 * nbytes / max(1, self.npes)
        return cutover.overlap_efficiency(
            nbytes, self.npes, work_items=self.tuning.work_group_size,
            tier="ici", hw=self.hw, tuning=self.tuning,
            step_compute_bytes=step_compute_bytes)

    # -- collectives ---------------------------------------------------------
    def _psum_rs_ag(self, x, axis_name):
        """Chunked RS+AG allreduce over padded (npes, k) rows."""
        rows, shape, pad = self._rows(x)
        full = kops.ring_allreduce(rows, axis_name=axis_name, npes=self.npes)
        flat = full.reshape(-1)
        if pad:
            flat = flat[:-pad]
        return flat.reshape(shape)

    def psum(self, x, axis_name):
        nbytes = int(x.size * x.dtype.itemsize)
        path = self._choose(nbytes)
        self._note("psum", x, path)
        if path == "direct" and nbytes <= 1 << 16:
            # paper §III-G2 small reduce: fcollect + duplicated local compute
            gathered = kops.ring_allgather(x, axis_name=axis_name,
                                           npes=self.npes)
            return gathered.sum(axis=0)
        return self._psum_rs_ag(x, axis_name)

    def psum_overlap(self, x, axis_name):
        """Allreduce via the nbi ring step (paper §III-F overlap): every
        step's neighbor transfer is in flight while the previous chunk's
        tile-add computes — the adds are off the transfer chain's critical
        path, so comm and compute genuinely overlap in the dataflow graph.
        The pass-around schedule moves npes*n bytes (vs 2n for RS+AG), so
        large messages fall back to the chunked RS+AG path, whose overlap is
        the modeled double-buffered schedule."""
        nbytes = int(x.size * x.dtype.itemsize)
        self._note_overlap("psum_nbi", x, overlap=True)
        if nbytes * self.npes <= 2 * (1 << 20):      # wire-cost break-even
            return kops.ring_allreduce_nbi(x, axis_name=axis_name,
                                           npes=self.npes)
        return self._psum_rs_ag(x, axis_name)

    def all_gather(self, x, axis_name):
        self._note("all_gather", x)
        return kops.ring_allgather(x, axis_name=axis_name, npes=self.npes)

    def reduce_scatter(self, x, axis_name):
        self._note("reduce_scatter", x)
        return kops.ring_reduce_scatter(x, axis_name=axis_name,
                                        npes=self.npes)

    def broadcast(self, x, axis_name, root=0):
        self._note("broadcast", x)
        return kops.push_broadcast(x, axis_name=axis_name, npes=self.npes,
                                   root=root)

    def ppermute(self, x, axis_name, perm):
        # ring permutation == neighbor put (device-initiated)
        offsets = {s: (d - s) % self.npes for s, d in perm}
        off = offsets.get(0, 1)
        self._note("ppermute", x)
        return kops.remote_put(x, axis_name=axis_name, npes=self.npes,
                               target_offset=off,
                               work_items=self.tuning.work_group_size)

    def psum_hierarchical(self, x, ici_axis, dcn_axis):
        """Two-level allreduce mirroring the paper's transport tiers:

        1. ring reduce-scatter over the intra-pod ``ici_axis`` —
           device-initiated direct path (Xe-Link analogue);
        2. allreduce of the (1/npes-sized) shards across the ``dcn_axis`` —
           the scale-out tier, which the paper reverse-offloads to the host
           proxy + NIC; here: an XLA DCN collective;
        3. ring all-gather back over ``ici_axis``.

        Wire per device: 2·s·(n-1)/n over ICI + 2·(s/n)·(p-1)/p over DCN —
        the DCN (scarce) tier carries only 1/npes of the payload.
        """
        rows, shape, pad = self._rows(x)
        mine = kops.ring_reduce_scatter(rows, axis_name=ici_axis,
                                        npes=self.npes)
        mine = jax.lax.psum(mine, dcn_axis)        # proxy/engine tier
        full = kops.ring_allgather(mine, axis_name=ici_axis, npes=self.npes)
        flat = full.reshape(-1)
        if pad:
            flat = flat[:-pad]
        return flat.reshape(shape)
