"""Paper Fig. 3: single-threaded Put/Get bandwidth vs message size across the
three fabric tiers (paper: same-tile / other-tile / other-GPU; TPU mapping:
local-HBM / ICI-neighbor / cross-pod-DCN), with the ze_peer-style engine
baseline for comparison.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cutover


def run():
    hw = cutover.HwParams()
    tiers = [("local", "same-device"), ("ici", "other-device"),
             ("dcn", "other-pod")]
    for op in ("put", "get"):
        for tier, label in tiers:
            for lb in range(7, 25):                      # 128 B .. 16 MB
                n = 1 << lb
                path = cutover.choose_path(n, work_items=1, tier=tier, hw=hw)
                t = cutover.op_time(n, path, work_items=1, tier=tier, hw=hw)
                bw = n / t / 1e9
                # ze_peer analogue: pure engine transfer at every size
                te = (cutover.t_engine(hw, n, tier) if tier != "dcn"
                      else cutover.t_proxy(hw, n, tier))
                emit(f"fig3_{op}", f"{label},{n}B", t * 1e6,
                     GBps=f"{bw:.2f}", path=path,
                     engine_GBps=f"{n / te / 1e9:.2f}")


if __name__ == "__main__":
    run()
