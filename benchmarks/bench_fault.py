"""Fault-tolerance benchmark: goodput through a mid-run pod loss.

One experiment, CI-gated: the same open-loop arrival schedule is served
twice — a no-fault control, and a chaos arm where ``kill_pod=pod1@K``
fail-stops half the fleet mid-benchmark.  The gate asserts the recovery
story end to end:

- **zero wrong tokens** — every request that survives the fault decodes
  bitwise-identically to the control run (casualties recompute/replay or
  shed; silent corruption is poisoned heap rows -> NaN -> caught here);
- **goodput recovers** — per-step good throughput (requests finishing
  inside their class deadline) dips at the fault and climbs back to
  >= 0.9x the pre-fault plateau once the survivors absorb the adopted
  load;
- **bounded recovery TTFD** — every recovered request is re-admitted to
  decode within a fixed step budget of the fault (re-migration or
  recompute, measured by the scheduler's ``recovery_steps`` ledger).

``smoke(json_path)`` emits BENCH_fault.json for ``scripts/ci.sh``.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import emit
from repro.configs import base as cfgbase
from repro.serve.engine import Engine
from repro.serve.frontend import (Fleet, FleetConfig, TenantSpec,
                                  TrafficEngine)
from repro.serve.frontend import slo as slo_mod
from repro.serve.scheduler import FINISHED

ARCH = "qwen3_4b"
SEED = 7
MAXLEN = 24
STEPS = 24              # open-loop arrival window (drain runs to empty)
RATE = 0.6              # below single-pod capacity: survivors CAN recover
KILL_STEP = 10          # mid-benchmark, pre-fault plateau established
RECOVERY_MARGIN = 4     # steps granted for re-migration/adoption to settle
WARMUP = 4              # steps excluded from the pre-fault plateau

MIX = (TenantSpec("chat", weight=2.0, prompt_lens=(8,), max_new=(4,),
                  slo="interactive"),
       TenantSpec("scan", weight=1.0, prompt_lens=(12,), max_new=(4,),
                  slo="batch", shared_prefix_prob=0.5, prefix_groups=1))


def _engine():
    import jax
    from repro.models import model
    cfg = cfgbase.reduced(cfgbase.get_config(ARCH))
    params = model.init_params(jax.random.key(0), cfg)
    return Engine(cfg, params, max_len=MAXLEN)


def _fleet(engine, fault_plan=None):
    fcfg = FleetConfig(n_pods=2, prefill_per_pod=1, decode_per_pod=2,
                       num_slots=2, kv_blocks=128, block_tokens=4,
                       max_len=MAXLEN, max_new=4, stream_chunks=1,
                       admission="fcfs", router="affinity",
                       queue_bound=64, seed=SEED)
    return Fleet(fcfg, engine=engine, fault_plan=fault_plan)


def _good_by_step(fleet) -> dict:
    """step -> requests that finished inside their class deadline there."""
    good = {}
    for pod in fleet.pods + fleet.dead_pods:
        for req in pod.sched.requests.values():
            if req.state != FINISHED:
                continue
            cls = slo_mod.resolve(req.slo, fleet.classes)
            if req.admit_step - req.arrival_step <= cls.ttfd_deadline:
                good[req.finish_step] = good.get(req.finish_step, 0) + 1
    return good


def _rate(good: dict, lo: int, hi: int) -> float:
    """Mean good completions per step over fleet steps [lo, hi)."""
    if hi <= lo:
        return 0.0
    return sum(n for s, n in good.items() if lo <= s < hi) / (hi - lo)


def pod_loss(engine) -> dict:
    """Control vs kill_pod mid-run on the identical arrival schedule."""
    traffic = TrafficEngine(list(MIX), rate=RATE,
                            vocab=cfgbase.reduced(
                                cfgbase.get_config(ARCH)).vocab_size,
                            seed=SEED)
    specs = traffic.schedule(STEPS)
    control = _fleet(engine)
    t0 = time.perf_counter()
    control.run(specs, max_steps=4000)
    co = control.outputs()

    plan = f"kill_pod=pod1@{KILL_STEP}"
    fleet = _fleet(engine, fault_plan=plan)
    rep = fleet.run(specs, max_steps=4000)
    wall_s = time.perf_counter() - t0
    fo = fleet.outputs()

    wrong = casualties = 0
    for spec in specs:
        got = list(fo[spec.idx]) if fo[spec.idx] is not None else []
        want = list(co[spec.idx])
        if not got:
            casualties += 1
        elif [int(t) for t in got] != [int(t) for t in want]:
            wrong += 1

    good = _good_by_step(fleet)
    recover_at = KILL_STEP + RECOVERY_MARGIN
    horizon = max(STEPS, max(good, default=0) + 1)
    pre = _rate(good, WARMUP, KILL_STEP)
    dip = _rate(good, KILL_STEP, recover_at)
    post = _rate(good, recover_at, horizon)
    recovery_steps = [s for pod in fleet.pods + fleet.dead_pods
                      for s in pod.sched.stats.recovery_steps]
    recov = rep["recovered"]
    return {
        "plan": plan,
        "rate": RATE,
        "offered": rep["offered"],
        "completed": rep["completed"],
        "wrong_tokens": wrong,
        "casualties": casualties,
        "pre_fault_good_per_step": pre,
        "dip_good_per_step": dip,
        "post_recovery_good_per_step": post,
        "recovery_ratio": post / pre if pre else 0.0,
        "recovered_requests": recov["recovered_requests"],
        "remigrated": recov["remigrated"],
        "recomputed": recov["recomputed"],
        "replayed_tokens": recov["replayed_tokens"],
        "recovery_ttfd_max_steps": max(recovery_steps, default=0),
        "recovery_ttfd_all_steps": sorted(recovery_steps),
        "cancelled_ops": rep["fault"]["cancelled_ops"],
        "elapsed_steps": rep["elapsed_steps"],
        "wall_s": wall_s,
    }


def run():
    engine = _engine()
    doc = pod_loss(engine)
    emit("fault_pod_loss", doc["plan"], 0.0,
         pre=f"{doc['pre_fault_good_per_step']:.3f}",
         dip=f"{doc['dip_good_per_step']:.3f}",
         post=f"{doc['post_recovery_good_per_step']:.3f}",
         ratio=f"{doc['recovery_ratio']:.2f}",
         wrong=doc["wrong_tokens"],
         recovered=doc["recovered_requests"],
         ttfd_max=doc["recovery_ttfd_max_steps"])


def smoke(json_path: str = "BENCH_fault.json") -> dict:
    """CI smoke: the pod-loss experiment -> JSON artifact."""
    engine = _engine()
    doc = {
        "bench": "fault_smoke",
        "arch": cfgbase.reduced(cfgbase.get_config(ARCH)).name,
        "pod_loss": pod_loss(engine),
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    p = doc["pod_loss"]
    emit("fault_smoke", json_path, 0.0,
         ratio=f"{p['recovery_ratio']:.2f}",
         wrong=p["wrong_tokens"], casualties=p["casualties"],
         recovered=p["recovered_requests"],
         ttfd_max=p["recovery_ttfd_max_steps"])
    return doc


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", nargs="?", const="BENCH_fault.json",
                    default=None, metavar="PATH",
                    help="CI smoke: goodput through a mid-run pod loss "
                         "(zero wrong tokens, >=0.9x recovery, bounded "
                         "recovery TTFD) -> JSON artifact")
    cli = ap.parse_args()
    if cli.smoke is not None:
        smoke(cli.smoke)
    else:
        run()
