"""Benchmark utilities.

Timing follows the paper's methodology (§IV): warm-up by doubling iterations
until total time exceeds 2 ms, then time ``trials`` runs.  Two aggregates
come out of the trial list: the **min** (the paper's best-of — the least
noise-contaminated run, what the CSV ``us_per_call`` column reports) and the
**trimmed median** (drop the top/bottom ``trim`` fraction, take the median
of the rest — robust to both cache-warm outliers and scheduler hiccups, the
statistic that feeds the tuner).  ``ISHMEM_BENCH_TRIALS`` overrides the
trial count process-wide; ``discard`` additionally times-but-drops the first
N runs after warm-up (JIT-retrace or page-fault shakeout).

Every benchmark prints CSV rows ``bench,config,us_per_call,derived...``.
Two kinds of numbers appear:
  - modeled : the cutover engine's TPU v5e projection (the apples-to-apples
              reproduction of the paper's figures), and
  - measured: wall-clock of the interpret-mode kernels / protocol machines on
              CPU (relative trends only; absolute CPU time is not TPU time).

Measured timings feed the autotuner: pass ``record=(op, nbytes, path, tier,
work_items)`` to :func:`best_of` and the trimmed-median wall-clock lands in
:data:`MEASURED` — a process-wide ``TelemetrySink`` — under the
``"wallclock"`` provenance stream, the same stream the serve profiler
(``repro.obs.prof``) writes.  ``benchmarks.run`` fits that stream after a
suite pass (``estimator.build_table(sample_source="wallclock")``), so fitted
tables carry measured provenance end to end (on real TPU hardware this IS
the paper's tuning loop; on CPU the fits are interpreter wall clock —
relative trends only — and kept out of the CI cutover gate, which compares
modeled numbers only).
"""
from __future__ import annotations

import os
import time

from repro.tune import telemetry as telemetry_mod

# wall-clock samples from every best_of(..., record=...) call in this process
MEASURED = telemetry_mod.TelemetrySink()


# single shared shim — tests/conftest.py applies the same one
from repro._jaxcompat import ensure_jax_compat  # noqa: F401


def _env_trials(default: int = 10) -> int:
    raw = os.environ.get("ISHMEM_BENCH_TRIALS")
    if raw is None or not raw.strip():
        return default
    try:
        val = int(raw)
    except ValueError:
        raise ValueError(f"ISHMEM_BENCH_TRIALS: expected an integer, "
                         f"got {raw!r}") from None
    if val < 1:
        raise ValueError("ISHMEM_BENCH_TRIALS must be >= 1")
    return val


def trimmed_median(times, trim: float = 0.2) -> float:
    """Median after dropping ``floor(n * trim)`` samples from EACH end of
    the sorted list.  With small n nothing is dropped and this is the plain
    median; never degenerates to an empty list."""
    xs = sorted(times)
    k = int(len(xs) * trim)
    if 2 * k >= len(xs):
        k = 0
    xs = xs[k:len(xs) - k] if k else xs
    mid = len(xs) // 2
    if len(xs) % 2:
        return xs[mid]
    return 0.5 * (xs[mid - 1] + xs[mid])


def best_of(fn, *, trials=None, min_warm_s: float = 0.002, record=None,
            discard: int = 0, trim: float = 0.2, details=None):
    """Paper methodology, hardened: double warm-up iterations until >2 ms,
    optionally time-and-discard ``discard`` more runs, then time ``trials``
    runs (default 10, ``ISHMEM_BENCH_TRIALS`` overrides).  Returns the min
    (back-compat: the paper's best-of).  ``record=(op, nbytes, path, tier,
    work_items)`` routes the TRIMMED MEDIAN into :data:`MEASURED` under
    ``source="wallclock"`` — the robust statistic feeds the tuner while the
    optimistic one stays in the CSV.  Pass a dict as ``details`` to receive
    ``{"min", "tmed", "trials", "discarded"}``."""
    if trials is None:
        trials = _env_trials()
    iters = 1
    while True:
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        dt = time.perf_counter() - t0
        if dt > min_warm_s:
            break
        iters *= 2
    for _ in range(discard):
        fn()
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    best = min(times)
    tmed = trimmed_median(times, trim)
    if details is not None:
        details.update(min=best, tmed=tmed, trials=trials,
                       discarded=discard)
    if record is not None:
        op, nbytes, path, tier, work_items = record
        MEASURED.record(telemetry_mod.OpRecord(
            op, int(nbytes), path, tier, tmed, int(work_items),
            telemetry_mod.WALLCLOCK_SOURCE))
    return best


def emit(bench: str, config: str, us_per_call: float, **derived):
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{bench},{config},{us_per_call:.3f},{extra}")
