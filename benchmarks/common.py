"""Benchmark utilities.

Timing follows the paper's methodology (§IV): warm-up by doubling iterations
until total time exceeds 2 ms, then take the best of 10 trials.

Every benchmark prints CSV rows ``bench,config,us_per_call,derived...``.
Two kinds of numbers appear:
  - modeled : the cutover engine's TPU v5e projection (the apples-to-apples
              reproduction of the paper's figures), and
  - measured: wall-clock of the interpret-mode kernels / protocol machines on
              CPU (relative trends only; absolute CPU time is not TPU time).
"""
from __future__ import annotations

import time


def best_of(fn, *, trials: int = 10, min_warm_s: float = 0.002):
    """Paper methodology: double warm-up iterations until >2 ms, then best
    of ``trials``."""
    iters = 1
    while True:
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        dt = time.perf_counter() - t0
        if dt > min_warm_s:
            break
        iters *= 2
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(bench: str, config: str, us_per_call: float, **derived):
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{bench},{config},{us_per_call:.3f},{extra}")
