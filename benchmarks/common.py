"""Benchmark utilities.

Timing follows the paper's methodology (§IV): warm-up by doubling iterations
until total time exceeds 2 ms, then take the best of 10 trials.

Every benchmark prints CSV rows ``bench,config,us_per_call,derived...``.
Two kinds of numbers appear:
  - modeled : the cutover engine's TPU v5e projection (the apples-to-apples
              reproduction of the paper's figures), and
  - measured: wall-clock of the interpret-mode kernels / protocol machines on
              CPU (relative trends only; absolute CPU time is not TPU time).

Measured timings feed the autotuner: pass ``record=(op, nbytes, path, tier,
work_items)`` to :func:`best_of` and the best wall-clock lands in
:data:`MEASURED` — a process-wide ``TelemetrySink`` that ``benchmarks.run``
fits after a suite pass, so fitted tables can reflect wall clock instead of
the analytic model replayed (on real TPU hardware this IS the paper's tuning
loop; on CPU the fits are tagged ``measured-wall-clock`` and kept out of the
CI cutover gate, which compares modeled numbers only).
"""
from __future__ import annotations

import time

from repro.tune import telemetry as telemetry_mod

# wall-clock samples from every best_of(..., record=...) call in this process
MEASURED = telemetry_mod.TelemetrySink()


# single shared shim — tests/conftest.py applies the same one
from repro._jaxcompat import ensure_jax_compat  # noqa: F401


def best_of(fn, *, trials: int = 10, min_warm_s: float = 0.002, record=None):
    """Paper methodology: double warm-up iterations until >2 ms, then best
    of ``trials``.  ``record=(op, nbytes, path, tier, work_items)`` routes
    the resulting best time into the :data:`MEASURED` telemetry sink."""
    iters = 1
    while True:
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        dt = time.perf_counter() - t0
        if dt > min_warm_s:
            break
        iters *= 2
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    if record is not None:
        op, nbytes, path, tier, work_items = record
        MEASURED.record(telemetry_mod.OpRecord(op, int(nbytes), path, tier,
                                               best, int(work_items)))
    return best


def emit(bench: str, config: str, us_per_call: float, **derived):
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{bench},{config},{us_per_call:.3f},{extra}")
