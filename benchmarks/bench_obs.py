"""Observability benchmarks: tracer overhead, trace schema, online re-fit,
invariant auditors, seeded faults, and SLO burn-rate alerting.

Six CI-gated experiments on the multi-pod fleet (``repro.obs`` riding on
``repro.serve.frontend``):

1. **tracer overhead** — the identical arrival schedule served with
   observability fully off (Null tracer: the production default) and with
   the span tracer + metrics registry recording.  Min-of-N wall clock,
   interleaved arms on one pre-warmed engine; the recording arm must stay
   within 2% of the off arm (gate a), and outputs must match bitwise.
2. **trace schema** — the recording arm's export must pass
   ``repro.obs.export.validate`` with zero violations (every event has
   pid/tid/ts, slice stacks balance, async spans and flows pair — gate b),
   every submitted request's lifeline must reconstruct gap-free from the
   async spans, and every complete critical path's segment attribution
   must sum to its end-to-end span exactly.
3. **online re-fit** — a heterogeneous-tier (multi-pod: local + ici + dcn
   wire) run warm-started from a deliberately STALE tuning table whose
   absurd cutovers pin every transfer to the direct path.  The periodic
   re-fit over live telemetry must hot-swap the table mid-run and flip at
   least one cutover decision back toward measured reality (gate c).
   (From a *clean* start the re-fit is a provable no-op here — live op
   timings are priced by the same analytic model ``choose_path`` falls
   back to — so the stale warm start is what makes the loop observable.)
4. **audit clean** — the per-step invariant auditors (``repro.obs.audit``)
   sweep a clean serve run with ZERO violations, and audit + flight-
   recorder work accounts for <3% of the run's wall clock (gate d).
5. **seeded faults** — one corruption per auditor family (refcount,
   residency, signal ledger) injected mid-flight; each must be caught
   within one audit period and leave a postmortem dump that validates
   clean (gate e).
6. **burn-rate alerts** — an overloaded run must fire the multi-window SLO
   burn-rate alert with a drill-down naming a request that truly missed
   its deadline; a nominal run must stay silent (gate f).

``smoke(json_path)`` emits BENCH_obs.json for ``scripts/ci.sh``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.configs import base as cfgbase
from repro.core import cutover
from repro.obs import Obs, chrome_trace, request_chains, validate
from repro.obs import critical
from repro.obs.export import chain_gaps
from repro.serve.engine import Engine
from repro.serve.frontend import (Fleet, FleetConfig, TenantSpec,
                                  TrafficEngine)
from repro.tune import table as table_mod

ARCH = "qwen3_4b"
SEED = 7
STEPS = 12              # open-loop arrival window (drain runs to empty)
OVERHEAD_STEPS = 8      # shorter window for the A/B timing arms
MAXLEN = 24
RATE = 1.5
TRIALS = 3

MIX = (TenantSpec("chat", weight=2.0, prompt_lens=(8,), max_new=(4,),
                  slo="interactive"),
       TenantSpec("scan", weight=1.0, prompt_lens=(12,), max_new=(8,),
                  slo="batch", shared_prefix_prob=0.5, prefix_groups=1))


def _engine():
    import jax
    from repro.models import model
    cfg = cfgbase.reduced(cfgbase.get_config(ARCH))
    params = model.init_params(jax.random.key(0), cfg)
    return Engine(cfg, params, max_len=MAXLEN)


def _build(engine, obs=None, *, rate=RATE, steps=STEPS, **over):
    """Fleet + its arrival schedule (not yet run)."""
    kw = dict(n_pods=2, prefill_per_pod=1, decode_per_pod=2,
              num_slots=1, kv_blocks=128, block_tokens=4,
              max_len=MAXLEN, max_new=4, stream_chunks=2,
              admission="slo", router="least_loaded",
              queue_bound=64, seed=SEED)
    kw.update(over)
    fleet = Fleet(FleetConfig(**kw), engine=engine, obs=obs)
    traffic = TrafficEngine(list(MIX), rate=rate,
                            vocab=fleet.cfg.vocab_size, seed=SEED)
    return fleet, traffic.schedule(steps)


def _serve(engine, obs=None, *, stale_table=None, rate=RATE, steps=STEPS,
           **over):
    fleet, specs = _build(engine, obs, rate=rate, steps=steps, **over)
    if stale_table is not None:
        fleet.ctx.tuning = cutover.Tuning(table=stale_table)
    t0 = time.perf_counter()
    rep = fleet.run(specs, max_steps=4000)
    return fleet, rep, time.perf_counter() - t0


def _tracer_event_cost_s() -> float:
    """Measured seconds per recorded tracer event (amortized over the mix
    of slice/async/instant emissions the fleet actually produces)."""
    from repro.obs import SpanTracer
    tr = SpanTracer()
    n = 20_000
    t0 = time.perf_counter()
    for i in range(n):
        tr.begin("flush", "cq", "core", "cq", ops=2)
        tr.instant("xfer", "cq", "core", "cq", path="direct", nbytes=4096)
        tr.end("flush", "cq", "core", "cq", bytes=4096)
        tr.async_begin("decoding", "req", i, "pod0", "requests", pe=2)
        tr.async_end("decoding", "req", i, "pod0", "requests")
    return (time.perf_counter() - t0) / (5 * n)


def _metrics_row_cost_s(fleet) -> float:
    """Measured seconds per sample_fleet row, on the drained fleet."""
    from repro.obs import MetricsRegistry, sample_fleet
    reg = MetricsRegistry()
    reps = 100
    t0 = time.perf_counter()
    for _ in range(reps):
        sample_fleet(reg, fleet)
    return (time.perf_counter() - t0) / reps


def overhead(engine) -> dict:
    """Gate (a): observability work must account for <2% of the fleet
    smoke's wall clock.

    The true tracer cost is a few thousand guarded list appends against
    seconds of jitted compute — far below this machine's run-to-run wall
    clock noise (+-10% under contention), so a naive A/B subtraction is
    hopelessly flaky at the 2% resolution the gate needs.  The gated
    number is therefore a deterministic accounting bound: (events emitted
    x measured per-event cost + metrics rows x measured per-row cost) over
    the off-arm's best wall clock.  The interleaved A/B minimum rides
    along as ``measured_overhead_pct`` (informational), and the off/on
    arms must stay bitwise-identical in outputs."""
    import gc
    _serve(engine, steps=OVERHEAD_STEPS)           # shared warm-up run
    best = {"off": float("inf"), "on": float("inf")}
    outs = {}
    last_on = None
    for _ in range(TRIALS):                        # interleave: drift-proof
        for arm in ("off", "on"):
            obs = Obs(trace=True, metrics=True) if arm == "on" else None
            gc.collect()
            fleet, _, dt = _serve(engine, obs, steps=OVERHEAD_STEPS)
            best[arm] = min(best[arm], dt)
            outs[arm] = fleet.outputs()
            if arm == "on":
                last_on = (fleet, obs)
    bitwise = set(outs["off"]) == set(outs["on"]) and all(
        np.array_equal(outs["off"][i], outs["on"][i]) for i in outs["off"])
    fleet_on, obs_on = last_on
    ev_cost = _tracer_event_cost_s()
    row_cost = _metrics_row_cost_s(fleet_on)
    n_events = len(obs_on.tracer.events) + obs_on.tracer.dropped
    n_rows = len(obs_on.metrics.series)
    obs_work_s = n_events * ev_cost + n_rows * row_cost
    return {
        "trials": TRIALS,
        "off_best_s": best["off"],
        "on_best_s": best["on"],
        "trace_events": n_events,
        "metrics_rows": n_rows,
        "tracer_event_cost_us": ev_cost * 1e6,
        "metrics_row_cost_us": row_cost * 1e6,
        "obs_work_s": obs_work_s,
        "overhead_pct": 100.0 * obs_work_s / best["off"],
        "measured_overhead_pct":
            100.0 * (best["on"] - best["off"]) / best["off"],
        "outputs_bitwise_identical": bool(bitwise),
    }


def trace_schema(engine) -> dict:
    """Gate (b): export validates clean; every lifeline reconstructs; every
    complete critical path's segment sum equals its e2e span exactly."""
    obs = Obs(trace=True, metrics=True)
    fleet, rep, _ = _serve(engine, obs)
    doc = chrome_trace(obs.tracer)
    errors = validate(doc)
    chains = request_chains(obs.tracer)
    rids = {rid for _, rid in fleet.placements.values()}
    gaps = sum(len(chain_gaps(c)) for c in chains.values())
    flows = [e for e in doc["traceEvents"] if e.get("ph") in ("s", "f")]
    paths = critical.fleet_paths(chains, obs.tracer.events)
    exact = sum(1 for p in paths.values()
                if p["complete"] and not p["gaps"]
                and abs(sum(p["segments"].values()) - p["e2e_ticks"]) < 1e-6)
    return {
        "events": len(doc["traceEvents"]),
        "dropped": obs.tracer.dropped,
        "validation_errors": errors,
        "requests": len(rids),
        "chains": len(chains),
        "chains_missing": sorted(rids - set(chains)),
        "chain_gaps": gaps,
        "flow_events": len(flows),
        "paths": len(paths),
        "paths_exact": exact,
        "metrics_rows": len(obs.metrics.series),
        "completed": rep["completed"],
    }


def _stale_table() -> table_mod.TuningTable:
    """Warm-start table with absurd cutovers: every local/ici transfer is
    pinned 'direct', contradicting the analytic model (and therefore the
    live telemetry, which the simulation prices with that model) at large
    sizes and small work-groups."""
    big = 1 << 30
    return table_mod.TuningTable(cutovers={
        ("local", 1): big, ("local", 512): big,
        ("ici", 1): big, ("ici", 512): big})


def refit_demo(engine) -> dict:
    """Gate (c): mid-run re-fit flips >=1 stale cutover decision."""
    obs = Obs(trace=True, refit_period=6, refit_min_samples=16)
    fleet, rep, _ = _serve(engine, obs, stale_table=_stale_table())
    events = [ev.to_json() for ev in obs.refitter.history]
    return {
        "refit_period_steps": 6,
        "refits": len(events),
        "decisions_changed": obs.refitter.decisions_changed(),
        "events": events,
        "completed": rep["completed"],
    }


def audit_clean(engine) -> dict:
    """Gate (d): the per-step invariant auditors sweep a clean run with
    zero violations, and audit + flight-recorder work stays under 3% of
    the run's wall clock (accounting bound, like gate a: host seconds
    spent auditing plus ring-buffer emissions x measured per-event cost)."""
    obs = Obs(metrics=True, audit_period=1, recorder_window=32)
    fleet, rep, dt = _serve(engine, obs)
    au = obs.auditor.summary()
    ev_cost = _tracer_event_cost_s()
    ring_events = len(obs.tracer.events) + obs.tracer.evicted
    obs_work_s = au["audit_seconds"] + ring_events * ev_cost
    return {
        "audit_period_steps": 1,
        "checks": au["checks"],
        "violations": au["violations"],
        "audit_seconds": au["audit_seconds"],
        "ring_events": ring_events,
        "ring_evicted": obs.tracer.evicted,
        "obs_work_s": obs_work_s,
        "overhead_pct": 100.0 * obs_work_s / dt,
        "recorder_dumps": len(obs.recorder.dumps),
        "completed": rep["completed"],
    }


def _fault_specs():
    """(when, corrupt) per auditor family — each corruption is injected
    mid-flight (prefix entries die with their last mapper, so a post-run
    poke would find nothing to corrupt)."""
    from repro.serve.scheduler import DECODING

    def refcount_when(f):
        return any(ids for ids in f.pool.block_tables.values())

    def refcount_corrupt(f):
        ids = next(ids for ids in f.pool.block_tables.values() if ids)
        f.pool._refcnt[ids[0]] += 1

    def residency_when(f):
        return any(e.refs >= 2 for e in f.prefix_index.values())

    def residency_corrupt(f):
        entry = max(f.prefix_index.values(), key=lambda e: e.refs)
        foreign = next(b for b in range(f.pool.num_blocks)
                       if b not in entry.block_ids)
        pe = f.pods[0].sched.decode_pes[0]
        entry.resident.setdefault(pe, set()).add(foreign)

    def _fresh_decoder(f):
        for pod in f.pods:
            for req in pod.sched.requests.values():
                if (req.state == DECODING and req.slot >= 0
                        and len(req.out) + 2 < req.max_new):
                    return req
        return None

    def signal_when(f):
        return _fresh_decoder(f) is not None

    def signal_corrupt(f):
        import jax.numpy as jnp
        req = _fresh_decoder(f)
        f.heap = f.heap.write(f.pool.sig_ptr(req.slot), req.decode_pe,
                              jnp.ones((1,), jnp.int32))

    return {"refcount": (refcount_when, refcount_corrupt),
            "residency": (residency_when, residency_corrupt),
            "signal": (signal_when, signal_corrupt)}


def seeded_faults(engine) -> dict:
    """Gate (e): each auditor family catches its seeded corruption within
    one audit period, with a postmortem dump that validates clean."""
    from repro.obs.audit import AuditError

    out = {}
    for name, (when, corrupt) in _fault_specs().items():
        with tempfile.TemporaryDirectory() as td:
            obs = Obs(audit_period=1, recorder_window=32,
                      recorder_path=os.path.join(td, f"pm_{name}.json"))
            fleet, specs = _build(engine, obs)
            specs = sorted(specs, key=lambda s: (s.step, s.idx))
            i, injected, caught, err = 0, None, None, None
            while i < len(specs) or not fleet.done():
                if fleet.elapsed_steps >= 4000:
                    break
                batch = []
                while (i < len(specs)
                       and specs[i].step <= fleet.elapsed_steps):
                    batch.append(specs[i])
                    i += 1
                if injected is None and when(fleet):
                    corrupt(fleet)
                    injected = fleet.elapsed_steps
                try:
                    fleet.step(batch)
                except AuditError as exc:
                    err, caught = exc, fleet.elapsed_steps
                    break
            rec = {
                "injected": injected is not None,
                "caught": err is not None,
                "violations": len(err.violations) if err else 0,
                "rules": (sorted({v.rule for v in err.violations})
                          if err else []),
                "caught_within_steps": (caught - injected
                                        if err and injected is not None
                                        else None),
                "dump_written": bool(obs.recorder.dumps),
            }
            if obs.recorder.dumps:
                with open(obs.recorder.dumps[0]) as f:
                    doc = json.load(f)
                warnings: list = []
                rec["dump_validation_errors"] = validate(doc,
                                                         warnings=warnings)
                rec["dump_reason"] = doc["otherData"]["postmortem"]["reason"]
            out[name] = rec
    return out


def alert_demo(engine) -> dict:
    """Gate (f): overload fires the burn-rate alert with a drill-down
    naming a request that truly missed its deadline; nominal load stays
    silent."""
    from repro.serve.frontend import slo as slo_mod
    from repro.serve.scheduler import FINISHED, SHED

    obs = Obs(trace=True, metrics=True, alerts=True)
    fleet, rep, _ = _serve(engine, obs, rate=4.0, queue_bound=2)
    offender_verified = False
    if obs.monitor.fired:
        alert = obs.monitor.fired[0]
        worst = alert.offenders[0] if alert.offenders else None
        if worst is not None:
            sched = {p.name: p.sched for p in fleet.pods}[worst["pod"]]
            req = sched.requests[worst["rid"]]
            cls = slo_mod.resolve(req.slo, fleet.classes)
            if worst["outcome"] == "shed":
                offender_verified = (req.state == SHED
                                     and cls.name == alert.cls)
            else:
                offender_verified = (
                    req.state == FINISHED and cls.name == alert.cls
                    and req.admit_step - req.arrival_step
                    > cls.ttfd_deadline)
    nominal = Obs(metrics=True, alerts=True)
    _serve(engine, nominal, rate=0.5)
    return {
        "overload_shed": rep["shed"],
        "overload_alerts": len(obs.monitor.fired),
        "overload_fired": bool(obs.monitor.fired),
        "offender_verified": offender_verified,
        "alerts": [a.to_json() for a in obs.monitor.fired],
        "nominal_alerts": len(nominal.monitor.fired),
        "nominal_silent": not nominal.monitor.fired,
    }


def measured_demo(engine) -> dict:
    """Gate (g): the measured-time profiling layer, three sub-experiments.

    1. **profiling-off bitwise** — the identical arrival schedule served
       with Obs(trace + audit) and with the wall-clock profiler ADDED (re-
       fit off in both arms: a re-fit consumes sample counts that differ by
       construction between arms).  Token outputs, the exported Chrome-trace
       document, and the audit roll-up must be identical — the profiler's
       perf_counter values must not perturb one deterministic bit — while
       the profiled arm must actually collect measured samples.
    2. **calibration** — the profiled arm's samples must produce a
       divergence report with >=1 populated (op, tier, size, work-items)
       bucket (the stream-flush scopes pair measured wall with nonzero
       modeled time by construction).
    3. **wallclock re-fit** — with profiling on and the re-fit loop armed,
       the online refitter must hot-swap a table fitted FROM the measured
       wallclock stream: table and fitted profiles carry
       ``source="wallclock"`` provenance.
    """
    # --- 1. bitwise off/on -------------------------------------------------
    arms = {}
    for arm, prof in (("off", False), ("on", True)):
        obs = Obs(trace=True, audit_period=2, prof=prof)
        fleet, rep, _ = _serve(engine, obs)
        arms[arm] = {
            "outputs": fleet.outputs(),
            "doc": json.dumps(chrome_trace(obs.tracer), sort_keys=True),
            "audit": {k: obs.auditor.summary()[k]
                      for k in ("checks", "violations")},
            "obs": obs,
            "completed": rep["completed"],
        }
    off, on = arms["off"], arms["on"]
    outputs_bitwise = set(off["outputs"]) == set(on["outputs"]) and all(
        np.array_equal(off["outputs"][i], on["outputs"][i])
        for i in off["outputs"])
    prof_on = on["obs"].prof
    doc_on = json.loads(on["doc"])
    bitwise = {
        "outputs_bitwise_identical": bool(outputs_bitwise),
        "trace_doc_identical": off["doc"] == on["doc"],
        "audit_identical": off["audit"] == on["audit"],
        "trace_validation_errors": validate(doc_on),
        "prof_samples": len(prof_on.samples),
        "prof_ops": sorted({s.op for s in prof_on.samples}),
    }
    # --- 2. calibration report over the measured samples -------------------
    from repro.obs import calibrate
    report = calibrate.report_from_samples(prof_on.samples)
    track = calibrate.measured_track_events(prof_on.samples)
    doc_with_track = chrome_trace(on["obs"].tracer, measured=track)
    calib = {
        "samples": report["samples"],
        "populated_buckets": report["populated_buckets"],
        "worst": report["worst"][:3],
        "unmodeled_wall_frac": report["coverage"]["unmodeled_wall_frac"],
        "measured_track_events": len(track),
        "track_doc_validation_errors": validate(doc_with_track),
        # the track is strictly additive: exporting WITHOUT it afterwards
        # still yields the byte-identical base document
        "track_additive": (json.dumps(chrome_trace(on["obs"].tracer),
                                      sort_keys=True) == on["doc"]
                           and len(doc_with_track["traceEvents"])
                           > len(doc_on["traceEvents"])),
    }
    # --- 3. wallclock re-fit ------------------------------------------------
    obs = Obs(prof=True, refit_period=4, refit_min_samples=8)
    fleet, rep, _ = _serve(engine, obs)
    tbl = fleet.ctx.tuning.table
    refit = {
        "refits": len(obs.refitter.history),
        "sample_source": obs.refitter.sample_source,
        "wallclock_samples": fleet.ctx.telemetry.nsamples("wallclock"),
        "table_armed": tbl is not None,
        "table_source": tbl.source if tbl is not None else None,
        "profiles": len(tbl.profiles) if tbl is not None else 0,
        "profile_sources": (sorted({p.source
                                    for p in tbl.profiles.values()})
                            if tbl is not None else []),
        "completed": rep["completed"],
    }
    return {"bitwise": bitwise, "calibration": calib, "refit": refit}


def run():
    engine = _engine()
    ov = overhead(engine)
    emit("obs_overhead", f"trials={ov['trials']}", 0.0,
         off_s=f"{ov['off_best_s']:.3f}", on_s=f"{ov['on_best_s']:.3f}",
         overhead_pct=f"{ov['overhead_pct']:.2f}",
         bitwise=ov["outputs_bitwise_identical"])
    ts = trace_schema(engine)
    emit("obs_trace", f"events={ts['events']}", 0.0,
         errors=len(ts["validation_errors"]), chains=ts["chains"],
         gaps=ts["chain_gaps"])
    rf = refit_demo(engine)
    emit("obs_refit", f"refits={rf['refits']}", 0.0,
         decisions_changed=rf["decisions_changed"])
    au = audit_clean(engine)
    emit("obs_audit", f"checks={au['checks']}", 0.0,
         violations=au["violations"],
         overhead_pct=f"{au['overhead_pct']:.2f}")
    sf = seeded_faults(engine)
    emit("obs_faults", ",".join(sorted(sf)), 0.0,
         caught=sum(1 for r in sf.values() if r["caught"]))
    al = alert_demo(engine)
    emit("obs_alerts", f"overload_alerts={al['overload_alerts']}", 0.0,
         offender_verified=al["offender_verified"],
         nominal_silent=al["nominal_silent"])
    ms = measured_demo(engine)
    emit("obs_measured", f"samples={ms['bitwise']['prof_samples']}", 0.0,
         bitwise=ms["bitwise"]["trace_doc_identical"],
         populated_buckets=ms["calibration"]["populated_buckets"],
         wallclock_refits=ms["refit"]["refits"],
         table_source=ms["refit"]["table_source"])


def smoke(json_path: str = "BENCH_obs.json") -> dict:
    """CI smoke: all three experiments -> JSON artifact."""
    engine = _engine()
    doc = {
        "bench": "obs_smoke",
        "arch": cfgbase.reduced(cfgbase.get_config(ARCH)).name,
        "overhead": overhead(engine),
        "trace": trace_schema(engine),
        "refit": refit_demo(engine),
        "audit": audit_clean(engine),
        "faults": seeded_faults(engine),
        "alerts": alert_demo(engine),
        "measured": measured_demo(engine),
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("obs_smoke", json_path, 0.0,
         overhead_pct=f"{doc['overhead']['overhead_pct']:.2f}",
         trace_errors=len(doc["trace"]["validation_errors"]),
         refit_decisions_changed=doc["refit"]["decisions_changed"],
         audit_violations=doc["audit"]["violations"],
         faults_caught=sum(1 for r in doc["faults"].values()
                           if r["caught"]),
         alert_fired=doc["alerts"]["overload_fired"],
         measured_bitwise=doc["measured"]["bitwise"]["trace_doc_identical"],
         measured_buckets=doc["measured"]["calibration"]
                             ["populated_buckets"],
         wallclock_refits=doc["measured"]["refit"]["refits"])
    return doc


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", nargs="?", const="BENCH_obs.json",
                    default=None, metavar="PATH",
                    help="CI smoke: overhead + trace schema + online "
                         "re-fit -> JSON artifact")
    cli = ap.parse_args()
    if cli.smoke is not None:
        smoke(cli.smoke)
    else:
        run()
