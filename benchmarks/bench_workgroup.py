"""Paper Fig. 4: work-group Put bandwidth vs message size for varying
work-items: (a) kernel-driven direct stores scale with work-items; (b) the
reverse-offloaded copy-engine path is flat in work-items.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cutover


def run():
    hw = cutover.HwParams()
    for wi in (1, 16, 128, 1024):
        for lb in range(7, 25):
            n = 1 << lb
            td = cutover.t_direct(hw, n, wi, "ici")          # Fig 4a
            te = cutover.t_engine(hw, n, "ici")              # Fig 4b
            emit("fig4a_store", f"wi={wi},{n}B", td * 1e6,
                 GBps=f"{n / td / 1e9:.2f}")
            emit("fig4b_engine", f"wi={wi},{n}B", te * 1e6,
                 GBps=f"{n / te / 1e9:.2f}")


if __name__ == "__main__":
    run()
