"""Paper §III-D: the reverse-offload ring — measured protocol throughput
(python state machine, relative) and the modeled hardware numbers the paper
reports (~5 us RTT, >20 M req/s, <1% flow-control overhead)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import cutover
from repro.core.ring import Message, RingBuffer


def run():
    hw = cutover.HwParams()
    # modeled hardware numbers (paper's reference points)
    emit("ring_model", "rtt", hw.alpha_engine * 1e6, note="engine startup "
         "includes reverse-offload round trip (paper ~5us)")
    emit("ring_model", "throughput", 1e6 / hw.ring_rate,
         Mreq_per_s=hw.ring_rate / 1e6)

    # measured protocol machine: msgs through the lock-free ring
    for n_prod in (1, 4, 16):
        ring = RingBuffer(slots=128, publish_every=16)
        N = 2000
        t0 = time.perf_counter()
        outstanding = []
        for m in range(N):
            pid = f"p{m}"
            ring.start(pid, Message("put"))
            while ring.producer_step(pid) is None:
                ring.consumer_step()
            outstanding.append(pid)
            if len(outstanding) >= n_prod:
                ring.consumer_step()
        while ring.consumer_step() is not None:
            pass
        dt = time.perf_counter() - t0
        assert ring.overwrite_errors == 0
        emit("ring_measured", f"producers={n_prod}", dt / N * 1e6,
             delivered=len(ring.delivered),
             flow_ctl_overhead=f"{ring.flow_control_overhead():.3%}")


if __name__ == "__main__":
    run()
