"""Paper Fig. 7: (a) cutover-tuned fcollect at 12 PEs across work-items;
(b) broadcast strong scaling over 2..12 PEs at 128 work-items (the 2-PE case
is the same-device fast path, as in the paper)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cutover
from repro.tune import env as env_mod


def run():
    hw = cutover.HwParams()
    # paper figure default is 128 work-items; ISHMEM_WORK_GROUP_SIZE moves it
    wgs = env_mod.tuning_from_env().work_group_size
    # (a) tuned fcollect, 12 PEs
    for wi in (256, 512, 1024):
        for le in range(4, 21):
            nelems = 1 << le
            nbytes = nelems * 4
            td = cutover.t_collective("fcollect", nbytes, 12,
                                      work_items=wi, path="direct", hw=hw)
            te = cutover.t_collective("fcollect", nbytes, 12, path="engine",
                                      hw=hw)
            emit("fig7a_fcollect_tuned", f"wi={wi},{nelems}el",
                 min(td, te) * 1e6,
                 path="direct" if td <= te else "engine")
    # (b) broadcast scaling in PEs
    for npes in (2, 4, 6, 8, 10, 12):
        hw_b = hw
        for le in range(4, 21):
            nelems = 1 << le
            nbytes = nelems * 4
            if npes == 2:
                # same-device pair: no inter-chip hop (paper: two tiles)
                t = cutover.t_collective("broadcast", nbytes, 2,
                                         work_items=wgs, path="direct",
                                         hw=cutover.HwParams(
                                             direct_bw_cap=hw.hbm_bw,
                                             direct_bw_per_item=6.4e9))
            else:
                td = cutover.t_collective("broadcast", nbytes, npes,
                                          work_items=wgs, path="direct",
                                          hw=hw_b)
                te = cutover.t_collective("broadcast", nbytes, npes,
                                          path="engine", hw=hw_b)
                t = min(td, te)
            emit("fig7b_broadcast", f"pes={npes},{nelems}el", t * 1e6,
                 MBps=f"{nbytes / t / 1e6:.1f}")


if __name__ == "__main__":
    run()
