"""Paged decode + chunked prefill streaming + shared prefixes (DESIGN.md §9).

Three experiments on the end-to-end serving path, all driven through the
real scheduler/migrator/pool machine (no synthetic byte-shuffling):

1. **TTFD** — the identical request workload served twice: whole-prefill
   migration (everything on the wire after prefill finishes) vs chunked
   streaming (`--stream-chunks` installments drain under later chunks'
   prefill compute).  The reported number is the modeled comm window
   between prefill-finish and admission (``stats.ttfd_model_s``) — the
   part of time-to-first-decode-token the migration protocol owns.
   Streaming must strictly shrink it (CI-gated).
2. **paged vs dense admission** — with paged decode the pool row IS the
   decode cache, so admission moves only the tail; the dense fallback
   rehydrates every payload byte into the slot bank.  Reported as modeled
   rehydrate time per admission (HBM-bound local copy) plus the end-to-end
   wall clock of both modes for reference.
3. **shared-prefix savings** — many-samples-one-prompt workload on one
   decode PE: physical blocks mapped instead of re-staged, wire bytes
   skipped for resident blocks, and the copy-on-write count that keeps the
   shared payloads pristine.

``smoke(json_path)`` is the CI entry point (BENCH_paged.json):
scripts/ci.sh asserts TTFD(streaming) < TTFD(whole-prefill) and that
prefix sharing actually shared blocks.
"""
from __future__ import annotations

import json
import time

import jax

from benchmarks.common import emit
from repro.configs import base as cfgbase
from repro.core import context, cutover
from repro.models import model
from repro.serve.engine import Engine, ServeConfig, SlotBatch
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator
from repro.serve.scheduler import DisaggScheduler

ARCH = "qwen3_4b"
PROMPT = 16
NEW = 6
N_REQ = 6
BLOCK_TOKENS = 4
MAXLEN = PROMPT + NEW


def _workload(*, stream_chunks=0, shared_prefix=False, paged=True,
              decode_pes=(2, 3), num_slots=2, same_prompt=False,
              admit_delay=1, n_req=N_REQ, S=PROMPT):
    cfg = cfgbase.reduced(cfgbase.get_config(ARCH))
    params = model.init_params(jax.random.key(0), cfg)
    ctx, heap = context.init(npes=4, node_size=4)
    eng = Engine(cfg, params, max_len=MAXLEN)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=48,
                         max_slots=max(num_slots, 3),
                         block_tokens=BLOCK_TOKENS)
    mig = KVMigrator(ctx, pool)
    sched = DisaggScheduler(
        ctx, heap, eng, pool, mig, prefill_pes=[0, 1],
        decode_pes=list(decode_pes), num_slots=num_slots,
        scfg=ServeConfig(max_new_tokens=NEW), admit_delay_steps=admit_delay,
        paged=paged, stream_chunks=stream_chunks,
        shared_prefix=shared_prefix)
    base = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    for i in range(n_req):
        p = base if same_prompt else jax.random.randint(
            jax.random.fold_in(jax.random.key(1), i), (1, S), 0,
            cfg.vocab_size)
        sched.submit({"tokens": p}, prefix_len=S if shared_prefix else 0)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    return sched, ctx, pool, wall


def _mean(xs):
    return sum(xs) / len(xs) if xs else 0.0


def _ttfd_pair(chunk: int = 1):
    """(whole_s, streaming_s, chunks): the same workload served both ways.

    Streams are slot-less now (DESIGN.md §10): chunks park in the pool and
    the decode slot binds only at stream close, so the slot is held for the
    tail+header window instead of the whole drain.  That lifted the old
    >= 2-slots-per-PE restriction — this pair runs at ONE slot per decode
    PE, the operating point where slot-bound streams used to tie
    whole-prefill (~0.9-1.1x) and parked streams win outright; the CI gate
    pins the win in exactly this regime."""
    s_whole, *_ = _workload(stream_chunks=0, num_slots=1, n_req=4)
    s_stream, *_ = _workload(stream_chunks=chunk, num_slots=1, n_req=4)
    return (_mean(s_whole.stats.ttfd_model_s),
            _mean(s_stream.stats.ttfd_model_s),
            s_stream.stats.stream_chunks)


def _rehydrate_model(pool, hw=None) -> tuple:
    """(seconds, bytes) of the dense rehydrate per admission: every payload
    byte of a full-prompt request plus the tail, copied HBM->HBM into the
    slot bank (the copy the paged path deletes)."""
    hw = hw or cutover.HwParams()
    lay = pool.layout
    nbytes = (lay.blocks_for_prompt(PROMPT) * lay.block_bytes
              + lay.tail_words * 4)
    return hw.alpha_direct + nbytes / hw.hbm_bw, nbytes


def run():
    whole, stream, chunks = _ttfd_pair()
    emit("paged_ttfd", "mode=whole-prefill", whole * 1e6)
    emit("paged_ttfd", f"mode=streaming,chunks={chunks}", stream * 1e6,
         improvement=f"{whole / stream:.2f}" if stream else "inf")

    s_paged, _, pool, wall_p = _workload(paged=True)
    s_dense, _, _, wall_d = _workload(paged=False)
    t_reh, nbytes = _rehydrate_model(pool)
    emit("paged_admission", "mode=paged", 0.0,
         rehydrate_bytes=0, wall_ms=f"{wall_p * 1e3:.1f}")
    emit("paged_admission", "mode=dense-rehydrate", t_reh * 1e6,
         rehydrate_bytes=nbytes, wall_ms=f"{wall_d * 1e3:.1f}")

    s_shared, _, _, _ = _workload(shared_prefix=True, same_prompt=True,
                                  decode_pes=(2,), num_slots=3, S=14)
    st = s_shared.stats
    emit("paged_prefix", f"requests={N_REQ}", 0.0,
         hits=st.prefix_hits, blocks_shared=st.blocks_prefix_shared,
         wire_saved=st.bytes_wire_saved, cow=st.cow_copies)


def smoke(json_path: str = "BENCH_paged.json") -> dict:
    """CI smoke: TTFD pair + prefix savings -> JSON artifact."""
    whole, stream, chunks = _ttfd_pair()
    # 14 % 4 != 0: the whole-prompt prefix shares a partial boundary block,
    # so the first divergent decode write exercises copy-on-write
    s_shared, _, pool, _ = _workload(shared_prefix=True, same_prompt=True,
                                     decode_pes=(2,), num_slots=3, S=14)
    st = s_shared.stats
    t_reh, nbytes = _rehydrate_model(pool)
    doc = {
        "bench": "paged_decode_smoke",
        "arch": cfgbase.reduced(cfgbase.get_config(ARCH)).name,
        "ttfd": {
            "whole_prefill_s": whole,
            "streaming_s": stream,
            "stream_chunks": chunks,
            "improvement": whole / stream if stream else float("inf"),
        },
        "paged_decode": {
            "rehydrate_bytes_per_admission_dense": nbytes,
            "rehydrate_s_per_admission_dense": t_reh,
            "rehydrate_bytes_per_admission_paged": 0,
        },
        "shared_prefix": {
            "requests": N_REQ,
            "prefix_hits": st.prefix_hits,
            "blocks_shared": st.blocks_prefix_shared,
            "bytes_wire_saved": st.bytes_wire_saved,
            "cow_copies": st.cow_copies,
        },
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("paged_smoke", json_path, stream * 1e6,
         ttfd_improvement=f"{doc['ttfd']['improvement']:.2f}",
         blocks_shared=st.blocks_prefix_shared)
    return doc


def measured() -> list:
    """Wall-clock measurement mode (``benchmarks.run --measured``).

    Times the pure-functional slot-bank decode step (``decode_slots`` —
    SlotBatch in, SlotBatch out, so every trial reruns the identical jitted
    step) across a (slots, context) sweep and records the trimmed median
    into the MEASURED sink's ``"wallclock"`` stream as ``serve_decode``
    engine/local samples — the same (op, path, tier) the serve profiler
    emits, so benches and live profiling fit into the same profile."""
    import numpy as np
    import jax.numpy as jnp
    from benchmarks import common
    from benchmarks.common import best_of

    cfg = cfgbase.reduced(cfgbase.get_config(ARCH))
    params = model.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params, max_len=MAXLEN)
    key = jax.random.key(2)
    rows = []
    for slots_n, pos_v in ((2, 4), (4, 8), (4, 16)):
        bank = eng.init_slots(slots_n)
        bank = SlotBatch(
            cache=bank.cache,
            pos=jnp.full((slots_n,), pos_v, jnp.int32),
            tok=jnp.ones((slots_n,), jnp.int32),
            active=np.ones((slots_n,), bool))
        # per-token KV footprint from the cache itself (the step reads the
        # resident context): total cache bytes spread over B x max_len
        cache_bytes = sum(leaf.nbytes
                          for leaf in jax.tree_util.tree_leaves(bank.cache))
        nbytes = int(cache_bytes // (slots_n * MAXLEN)) * pos_v * slots_n

        def step(bank=bank, key=key):
            _, tok = eng.decode_slots(bank, key)
            jax.block_until_ready(tok)

        details = {}
        best_of(step, discard=1, details=details,
                record=("serve_decode", nbytes, "engine", "local", slots_n))
        emit("paged_decode_measured", f"slots={slots_n},ctx={pos_v}",
             details["min"] * 1e6,
             tmed_us=f"{details['tmed'] * 1e6:.3f}",
             nbytes=nbytes, trials=details["trials"])
        rows.append({"slots": slots_n, "ctx": pos_v, "nbytes": nbytes,
                     "min_s": details["min"], "tmed_s": details["tmed"]})
    assert common.MEASURED.nsamples("wallclock") >= len(rows)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", nargs="?", const="BENCH_paged.json",
                    default=None, metavar="PATH",
                    help="CI smoke: TTFD streaming-vs-whole + prefix "
                         "savings -> JSON artifact")
    ap.add_argument("--measured", action="store_true",
                    help="wall-clock measurement mode: time the slot-bank "
                         "decode step across a (slots, context) sweep, "
                         "record trimmed medians into the wallclock "
                         "telemetry stream")
    cli = ap.parse_args()
    if cli.smoke is not None:
        smoke(cli.smoke)
    elif cli.measured:
        print("bench,config,us_per_call,derived")
        measured()
    else:
        run()
