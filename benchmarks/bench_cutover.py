"""Paper Fig. 5: cutover-tuned work-group Put — bandwidth and latency vs
message size at varying work-items.  Below the (work-item-dependent) cutover
the direct path is used; above it the engine path; the tuned curve tracks the
max of both (which is exactly what Fig. 5 shows).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cutover


def run():
    hw = cutover.HwParams()
    for wi in (1, 16, 128, 1024):
        co = cutover.cutover_bytes(work_items=wi, tier="ici", hw=hw)
        for lb in range(7, 25):
            n = 1 << lb
            path = cutover.choose_path(n, work_items=wi, tier="ici", hw=hw)
            t = cutover.op_time(n, path, work_items=wi, tier="ici", hw=hw)
            emit("fig5_tuned_put", f"wi={wi},{n}B", t * 1e6,
                 GBps=f"{n / t / 1e9:.2f}", path=path,
                 cutover_B=min(co, 1 << 40))


if __name__ == "__main__":
    run()
