"""Paper Fig. 5: cutover-tuned work-group Put — bandwidth and latency vs
message size at varying work-items.  Below the (work-item-dependent) cutover
the direct path is used; above it the engine path; the tuned curve tracks the
max of both (which is exactly what Fig. 5 shows).

``profile()`` is the autotuner's profile mode (``benchmarks.run --json``): it
runs a full (path x tier x work_items x size) tuning sweep through the
telemetry sink, fits measured transport profiles + cutovers with
``repro.tune.estimator``, and emits ``BENCH_cutover.json`` — the artifact
``ISHMEM_TUNING_FILE`` warm-starts later sessions from.
"""
from __future__ import annotations

import json

from benchmarks.common import emit
from repro.core import cutover
from repro.tune import estimator
from repro.tune.estimator import (DEFAULT_TIERS as TIERS,
                                  DEFAULT_WORK_ITEMS as WORK_ITEMS)
from repro.tune.table import INF_CUTOVER


def run():
    hw = cutover.HwParams()
    for wi in WORK_ITEMS:
        co = cutover.cutover_bytes(work_items=wi, tier="ici", hw=hw)
        for lb in range(7, 25):
            n = 1 << lb
            path = cutover.choose_path(n, work_items=wi, tier="ici", hw=hw)
            t = cutover.op_time(n, path, work_items=wi, tier="ici", hw=hw)
            emit("fig5_tuned_put", f"wi={wi},{n}B", t * 1e6,
                 GBps=f"{n / t / 1e9:.2f}", path=path,
                 cutover_B=min(co, 1 << 40))


def profile(json_path: str = "BENCH_cutover.json",
            hw: cutover.HwParams | None = None) -> dict:
    """Tuning sweep -> fitted table -> ``BENCH_cutover.json``.  Returns the
    written document (also used by the CI regression gate)."""
    hw = hw or cutover.HwParams()
    sink = estimator.synthetic_sweep(hw, work_items=WORK_ITEMS)
    tbl = estimator.build_table(sink, source="bench_cutover.profile")
    agree = estimator.agreement(tbl, hw, work_items=WORK_ITEMS)
    analytic = {
        f"{tier}/{wi}": min(cutover.cutover_bytes(work_items=wi, tier=tier,
                                                  hw=hw), INF_CUTOVER)
        for tier in TIERS for wi in WORK_ITEMS
    }
    doc = {
        "bench": "cutover_profile",
        "samples": sink.total_count(),
        "agreement_vs_analytic": agree,
        "analytic_cutovers": {k: (None if v >= INF_CUTOVER else v)
                              for k, v in analytic.items()},
        "table": tbl.to_json(),
        "telemetry": sink.snapshot(),
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("cutover_profile", f"{json_path}", 0.0,
         samples=sink.total_count(), agreement=f"{agree:.3f}")
    return doc


if __name__ == "__main__":
    run()
