import os

# the ring-kernel benches exercise 8 simulated PEs (this is a separate process
# from tests and from the 512-device dry-run)
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Benchmark harness — one module per paper table/figure.

Prints ``bench,config,us_per_call,derived...`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,...]
"""
import argparse


def main() -> None:
    from benchmarks import common
    common.ensure_jax_compat()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--json", nargs="?", const="BENCH_cutover.json",
                    default=None, metavar="PATH",
                    help="profile mode: run the cutover tuning sweep and emit "
                         "a persisted TuningTable (default BENCH_cutover.json)")
    args = ap.parse_args()

    if args.json is not None:
        import os
        from benchmarks import (bench_cutover, bench_device, bench_fleet,
                                bench_kvxfer, bench_paged_decode)
        print("bench,config,us_per_call,derived")
        doc = bench_cutover.profile(args.json)
        print(f"# wrote {args.json}: {doc['samples']} samples, "
              f"agreement={doc['agreement_vs_analytic']:.3f}")
        out_dir = os.path.dirname(args.json) or "."
        kv_path = os.path.join(out_dir, "BENCH_kvxfer.json")
        kv = bench_kvxfer.smoke(kv_path)
        print(f"# wrote {kv_path}: overlap "
              f"{kv['overlap']['overlap_ratio']:.2f}x, coalescing "
              f"{kv['migration']['coalescing_ratio']:.1f}")
        pg_path = os.path.join(out_dir, "BENCH_paged.json")
        pg = bench_paged_decode.smoke(pg_path)
        print(f"# wrote {pg_path}: streaming TTFD "
              f"{pg['ttfd']['improvement']:.2f}x, "
              f"{pg['shared_prefix']['blocks_shared']} blocks shared")
        dv_path = os.path.join(out_dir, "BENCH_device.json")
        dv = bench_device.smoke(dv_path)
        ab_dv = dv["fused_vs_barrier"]
        print(f"# wrote {dv_path}: fused TTFD "
              f"{ab_dv['ttfd_model_improvement']:.2f}x "
              f"(bitwise={ab_dv['bitwise_identical']}), ring overlap "
              f"{dv['ring_attention']['overlap_ratio']:.2f}x")
        fl_path = os.path.join(out_dir, "BENCH_fleet.json")
        fl = bench_fleet.smoke(fl_path)
        ab = fl["slo_vs_fcfs"]
        print(f"# wrote {fl_path}: interactive p99 TTFD "
              f"{ab['fcfs']['interactive_ttfd_p99_steps']:.1f} (fcfs) -> "
              f"{ab['slo']['interactive_ttfd_p99_steps']:.1f} (slo) steps, "
              f"{fl['goodput']['points'][-1]['shed']} shed past saturation")
        return

    from benchmarks import (bench_broadcast, bench_cutover, bench_device,
                            bench_fcollect, bench_fleet, bench_kernels,
                            bench_kvxfer, bench_overlap, bench_paged_decode,
                            bench_ring, bench_rma, bench_workgroup, common)
    suites = [
        ("fig3_rma", bench_rma.run),
        ("fig4_workgroup", bench_workgroup.run),
        ("fig5_cutover", bench_cutover.run),
        ("fig6_fcollect", bench_fcollect.run),
        ("fig7_broadcast", bench_broadcast.run),
        ("ring_buffer", bench_ring.run),
        ("kernels", bench_kernels.run),
        ("overlap", bench_overlap.run),
        ("kvxfer", bench_kvxfer.run),
        ("paged_decode", bench_paged_decode.run),
        ("device", bench_device.run),
        ("fleet", bench_fleet.run),
    ]
    only = args.only.split(",") if args.only else None
    print("bench,config,us_per_call,derived")
    for name, fn in suites:
        if only and not any(o in name for o in only):
            continue
        fn()

    # fit whatever wall-clock samples the suites recorded (benchmarks pass
    # record= to best_of) — the measured half of the tuning loop.  On CPU the
    # fits are interpreter wall clock (relative trends only), so the table is
    # written to a separate artifact and never fed to the CI cutover gate;
    # on TPU this file IS a hardware-truth ISHMEM_TUNING_FILE.
    if common.MEASURED.total_count():
        from repro.tune import estimator
        tbl = estimator.build_table(common.MEASURED,
                                    source="measured-wall-clock")
        if tbl.profiles or tbl.cutovers:
            tbl.save("BENCH_measured.json")
            print(f"# wrote BENCH_measured.json: "
                  f"{common.MEASURED.total_count()} wall-clock samples, "
                  f"{len(tbl.profiles)} fitted profiles")


if __name__ == "__main__":
    main()
