import os

# the ring-kernel benches exercise 8 simulated PEs (this is a separate process
# from tests and from the 512-device dry-run)
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Benchmark harness — one module per paper table/figure.

Prints ``bench,config,us_per_call,derived...`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,...]
"""
import argparse


def _flush_measured(out_dir: str = ".") -> None:
    """Fit whatever wall-clock samples the suites recorded (benchmarks pass
    ``record=`` to ``best_of``) and persist the table — the measured half of
    the tuning loop.  Samples live in the sink's ``"wallclock"`` provenance
    stream, so the fit runs with ``sample_source="wallclock"`` and the table
    (and every profile in it) carries that provenance into the JSON.  On CPU
    the fits are interpreter wall clock (relative trends only), so the table
    is a separate artifact never fed to the CI cutover gate; on TPU this
    file IS a hardware-truth ``ISHMEM_TUNING_FILE``."""
    from benchmarks import common
    from repro.tune import estimator
    n = common.MEASURED.nsamples("wallclock")
    if not n:
        return
    tbl = estimator.build_table(common.MEASURED, source="wallclock",
                                sample_source="wallclock")
    if tbl.profiles or tbl.cutovers:
        path = os.path.join(out_dir, "BENCH_measured.json")
        tbl.save(path)
        print(f"# wrote {path}: {n} wall-clock samples, "
              f"{len(tbl.profiles)} fitted profiles "
              f"(source={tbl.source})")


def _measured_mode(out_dir: str = ".") -> None:
    """``--measured``: run the wall-clock measurement benches, flush the
    fitted table, and validate the whole loop end to end — the emitted
    ``BENCH_measured.json`` must warm-start a fresh context through
    ``ISHMEM_TUNING_FILE`` with ``"wallclock"`` provenance intact, including
    through a ``TuningTable.merge``."""
    from benchmarks import bench_kvxfer, bench_paged_decode, common
    from repro.core import context
    from repro.tune import table as table_mod

    print("bench,config,us_per_call,derived")
    bench_kvxfer.measured()
    bench_paged_decode.measured()
    _flush_measured(out_dir)
    path = os.path.join(out_dir, "BENCH_measured.json")
    if not os.path.exists(path):
        raise SystemExit("--measured: no fitted table was written — the "
                         "measurement benches recorded too few samples")
    # round-trip gate 1: the file warm-starts a context (the paper's
    # persisted-tuning path) and the armed table carries its provenance
    os.environ["ISHMEM_TUNING_FILE"] = path
    try:
        ctx, _ = context.init(npes=2, node_size=2)
    finally:
        del os.environ["ISHMEM_TUNING_FILE"]
    tbl = ctx.tuning.table
    assert tbl is not None and (tbl.profiles or tbl.cutovers), \
        "--measured: ISHMEM_TUNING_FILE did not arm the table"
    assert "wallclock" in tbl.source, \
        f"--measured: table source lost provenance: {tbl.source!r}"
    assert all("wallclock" in p.source for p in tbl.profiles.values()), \
        "--measured: a fitted profile lost wallclock provenance"
    # round-trip gate 2: merge keeps per-profile provenance (no laundering)
    merged = tbl.merge(table_mod.TuningTable(source="model"))
    assert all("wallclock" in p.source for p in merged.profiles.values()), \
        "--measured: merge dropped wallclock provenance"
    print(f"# measured loop validated: {path} -> ISHMEM_TUNING_FILE "
          f"warm-start armed {len(tbl.profiles)} profile(s), "
          f"source={tbl.source}, merge preserves provenance")


def main() -> None:
    from benchmarks import common
    common.ensure_jax_compat()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--json", nargs="?", const="BENCH_cutover.json",
                    default=None, metavar="PATH",
                    help="profile mode: run the cutover tuning sweep and emit "
                         "a persisted TuningTable (default BENCH_cutover.json)")
    ap.add_argument("--measured", action="store_true",
                    help="wall-clock measurement mode: run the measured "
                         "kvxfer/paged-decode benches (best_of record=), fit "
                         "the wallclock telemetry stream into "
                         "BENCH_measured.json, and validate the "
                         "ISHMEM_TUNING_FILE warm-start round trip")
    args = ap.parse_args()

    if args.measured:
        _measured_mode()
        return

    if args.json is not None:
        from benchmarks import (bench_cutover, bench_device, bench_fleet,
                                bench_kvxfer, bench_paged_decode)
        print("bench,config,us_per_call,derived")
        doc = bench_cutover.profile(args.json)
        print(f"# wrote {args.json}: {doc['samples']} samples, "
              f"agreement={doc['agreement_vs_analytic']:.3f}")
        out_dir = os.path.dirname(args.json) or "."
        kv_path = os.path.join(out_dir, "BENCH_kvxfer.json")
        kv = bench_kvxfer.smoke(kv_path)
        print(f"# wrote {kv_path}: overlap "
              f"{kv['overlap']['overlap_ratio']:.2f}x, coalescing "
              f"{kv['migration']['coalescing_ratio']:.1f}")
        pg_path = os.path.join(out_dir, "BENCH_paged.json")
        pg = bench_paged_decode.smoke(pg_path)
        print(f"# wrote {pg_path}: streaming TTFD "
              f"{pg['ttfd']['improvement']:.2f}x, "
              f"{pg['shared_prefix']['blocks_shared']} blocks shared")
        dv_path = os.path.join(out_dir, "BENCH_device.json")
        dv = bench_device.smoke(dv_path)
        ab_dv = dv["fused_vs_barrier"]
        print(f"# wrote {dv_path}: fused TTFD "
              f"{ab_dv['ttfd_model_improvement']:.2f}x "
              f"(bitwise={ab_dv['bitwise_identical']}), ring overlap "
              f"{dv['ring_attention']['overlap_ratio']:.2f}x")
        fl_path = os.path.join(out_dir, "BENCH_fleet.json")
        fl = bench_fleet.smoke(fl_path)
        ab = fl["slo_vs_fcfs"]
        print(f"# wrote {fl_path}: interactive p99 TTFD "
              f"{ab['fcfs']['interactive_ttfd_p99_steps']:.1f} (fcfs) -> "
              f"{ab['slo']['interactive_ttfd_p99_steps']:.1f} (slo) steps, "
              f"{fl['goodput']['points'][-1]['shed']} shed past saturation")
        # profile mode runs suites that record wall clock too — flush them
        # (this branch used to return without flushing, silently dropping
        # every best_of(record=) sample)
        _flush_measured(out_dir)
        return

    from benchmarks import (bench_broadcast, bench_cutover, bench_device,
                            bench_fcollect, bench_fleet, bench_kernels,
                            bench_kvxfer, bench_overlap, bench_paged_decode,
                            bench_ring, bench_rma, bench_workgroup, common)
    suites = [
        ("fig3_rma", bench_rma.run),
        ("fig4_workgroup", bench_workgroup.run),
        ("fig5_cutover", bench_cutover.run),
        ("fig6_fcollect", bench_fcollect.run),
        ("fig7_broadcast", bench_broadcast.run),
        ("ring_buffer", bench_ring.run),
        ("kernels", bench_kernels.run),
        ("overlap", bench_overlap.run),
        ("kvxfer", bench_kvxfer.run),
        ("paged_decode", bench_paged_decode.run),
        ("device", bench_device.run),
        ("fleet", bench_fleet.run),
    ]
    only = args.only.split(",") if args.only else None
    print("bench,config,us_per_call,derived")
    for name, fn in suites:
        if only and not any(o in name for o in only):
            continue
        fn()

    _flush_measured()


if __name__ == "__main__":
    main()
