"""Device-initiated SHMEM (DESIGN.md §12): the four headline checks.

1. **fused vs barrier TTFD** — one long-prompt request served twice through
   the real scheduler/migrator/pool machine: barrier admission (wait for
   ``sent + 2``) vs fused admission (``migrate_fused`` + per-block device
   waits).  Outputs must be bitwise-identical; the fused mode must strictly
   shrink both the modeled comm window (``stats.ttfd_model_s`` — first-block
   flush instead of whole-request flush) and the step-level TTFD (the admit
   delay scales with the admission threshold).  Single request on purpose:
   per-block signals forfeit write-combined runs, so the cumulative
   multi-request comm clock is the wrong objective — the win fused buys is
   *per-request* time-to-first-token, which is what this gate pins.
2. **ring-attention overlap** — numeric check of the sequence-parallel ring
   (``kernels.ishmem_device.ring_attention``) against full flash attention,
   plus the modeled long-context overlap ratio
   (``cutover.ring_attention_overlap``): the device-initiated rotate-while-
   compute schedule must beat blocking by >= 1.2x at 32k context.
3. **work-group-resolved cutover fit** — a ``device.put`` sweep at several
   collaboration widths through a telemetry-armed context; the fitted table
   must contain a measured (tier, work_group_size) cutover for every width
   swept — proof the device ops feed the autotuner at their own width.
4. **trace coverage** — the same device ops under a recording SpanTracer:
   the exported Chrome trace must carry ``device_*`` events.

``smoke(json_path)`` writes BENCH_device.json; scripts/ci.sh gates on it.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import base as cfgbase
from repro.core import context, cutover, device as device_mod, rma
from repro.kernels import ops
from repro.models import model
from repro.obs import export as export_mod
from repro.obs.tracer import SpanTracer
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator
from repro.serve.scheduler import DisaggScheduler

ARCH = "qwen3_4b"
PROMPT = 20                      # 5 wire blocks at T=4: real per-block ramp
NEW = 4
MAXLEN = PROMPT + NEW
BLOCK_TOKENS = 4
ADMIT_DELAY = 3                  # step-level TTFD visible only with delay > 0
WG_SIZES = (32, 128, 512)        # collaboration widths the sweep fits
SWEEP_SIZES = tuple(1 << b for b in range(7, 25, 2))    # 128 B .. 8 MB
RING_NPES = 4
RING_SEQ_MODEL = 32768           # long-context operating point (modeled)
RING_SEQ_NUMERIC = 256           # small instance for the bitwise-ish check


# ---------------------------------------------------------------------------
# 1. fused vs barrier admission A/B
# ---------------------------------------------------------------------------


def _serve_once(*, fused: bool):
    """One long-prompt request end to end; returns (tokens, ttfd_model_s,
    ttfd_steps, first_block_steps)."""
    cfg = cfgbase.reduced(cfgbase.get_config(ARCH))
    params = model.init_params(jax.random.key(0), cfg)
    ctx, heap = context.init(npes=4, node_size=4)
    eng = Engine(cfg, params, max_len=MAXLEN)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=32, max_slots=3,
                         block_tokens=BLOCK_TOKENS)
    mig = KVMigrator(ctx, pool)
    sched = DisaggScheduler(
        ctx, heap, eng, pool, mig, prefill_pes=[0, 1], decode_pes=[2],
        num_slots=1, scfg=ServeConfig(max_new_tokens=NEW),
        admit_delay_steps=ADMIT_DELAY, paged=True, fused_attn=fused)
    p = jax.random.randint(jax.random.key(1), (1, PROMPT), 0, cfg.vocab_size)
    sched.submit({"tokens": p})
    outs = sched.run()
    req = next(iter(sched.requests.values()))
    return (np.asarray(outs[0]),
            float(np.mean(sched.stats.ttfd_model_s)),
            req.admit_step - req.arrival_step,
            req.first_block_step - req.arrival_step)


def _fused_ab() -> dict:
    tok_b, model_b, steps_b, fb_b = _serve_once(fused=False)
    tok_f, model_f, steps_f, fb_f = _serve_once(fused=True)
    return {
        "bitwise_identical": bool(np.array_equal(tok_b, tok_f)),
        "barrier": {"ttfd_model_s": model_b, "ttfd_steps": steps_b,
                    "first_block_steps": fb_b},
        "fused": {"ttfd_model_s": model_f, "ttfd_steps": steps_f,
                  "first_block_steps": fb_f},
        "ttfd_model_improvement": model_b / model_f if model_f else 0.0,
    }


# ---------------------------------------------------------------------------
# 2. sequence-parallel ring attention
# ---------------------------------------------------------------------------


def _ring_overlap() -> dict:
    # numeric: the ring schedule reproduces full causal flash attention
    B, H, hd = 1, 2, 32
    key = jax.random.key(7)
    kq, kk, kv = jax.random.split(key, 3)
    shape = (B, RING_SEQ_NUMERIC, H, hd)
    q = jax.random.normal(kq, shape, jnp.float32)
    k = jax.random.normal(kk, shape, jnp.float32)
    v = jax.random.normal(kv, shape, jnp.float32)
    ring = ops.ring_attention(q, k, v, npes=RING_NPES)
    ref = ops.flash_attention(q, k, v)
    max_err = float(jnp.max(jnp.abs(ring - ref)))

    # modeled: long-context operating point at the FULL (unreduced) config —
    # flash is bandwidth-bound, so a step's compute cost is the bytes it
    # touches (q + k + v + o of the resident shard), not its FLOPs
    full = cfgbase.get_config(ARCH)
    sh = RING_SEQ_MODEL // RING_NPES
    kv_bytes = 2 * sh * full.d_model * 4
    compute_bytes = 4 * sh * full.d_model * 4
    kw = dict(npes=RING_NPES, tier="ici")
    tb = cutover.t_ring_attention(kv_bytes, compute_bytes, overlap=False,
                                  **kw)
    tn = cutover.t_ring_attention(kv_bytes, compute_bytes, overlap=True, **kw)
    return {
        "npes": RING_NPES,
        "seq_numeric": RING_SEQ_NUMERIC,
        "numeric_max_err": max_err,
        "seq_model": RING_SEQ_MODEL,
        "kv_bytes_per_shard": kv_bytes,
        "compute_bytes_per_step": compute_bytes,
        "t_blocking_s": tb,
        "t_overlap_s": tn,
        "overlap_ratio": tb / tn if tn else 1.0,
    }


# ---------------------------------------------------------------------------
# 3. work-group-resolved cutover fit
# ---------------------------------------------------------------------------


def _cutover_fit() -> dict:
    """device.put sweep at each collaboration width -> fitted table; the
    measured (ici, wgs) cutover must exist for every width swept."""
    ctx, heap = context.init(npes=4, node_size=4, heap_words=1 << 22)
    buf = heap.malloc((max(SWEEP_SIZES) // 4,), jnp.float32)
    for wgs in WG_SIZES:
        wg = device_mod.work_group(ctx, size=wgs, pe=0)
        for nbytes in SWEEP_SIZES:
            view = rma.SymPtr("float32", buf.offset, (nbytes // 4,))
            heap = device_mod.put(wg, heap, view,
                                  jnp.zeros(nbytes // 4, jnp.float32), 1)
    tbl = ctx.fit_tuning_table(arm=True)
    fitted = {f"{tier}/{wi}": int(co)
              for (tier, wi), co in sorted(tbl.cutovers.items())}
    present = [("ici", wgs) in tbl.cutovers for wgs in WG_SIZES]
    return {
        "work_group_sizes": list(WG_SIZES),
        "sweep_sizes": len(SWEEP_SIZES),
        "fitted_cutovers": fitted,
        "all_widths_fitted": all(present),
        "armed": ctx.tuning.table is not None,
    }


# ---------------------------------------------------------------------------
# 4. trace coverage
# ---------------------------------------------------------------------------


def _trace_smoke() -> dict:
    """Every device op family under a recording tracer -> exported Chrome
    trace; counts the ``device_*`` events the observability gate needs."""
    ctx, heap = context.init(npes=4, node_size=4)
    ctx.tracer = SpanTracer()
    wg = device_mod.work_group(ctx, size=128, pe=0)
    buf = heap.malloc((256,), jnp.float32)
    sig = heap.malloc((1,), jnp.int32)
    heap = device_mod.put(wg, heap, buf, jnp.ones(256, jnp.float32), 1)
    _ = device_mod.get(wg, heap, buf, 1)
    heap = device_mod.put_signal_nbi(wg, heap, buf,
                                     jnp.full(256, 2.0, jnp.float32),
                                     sig, 1, device_mod.SIGNAL_ADD, 1)
    heap, _, ok = device_mod.signal_wait_until(wg, heap, sig, 1, "ge", 1)
    assert ok, "trace smoke: signal wait must satisfy"
    heap = device_mod.broadcast(wg, heap, buf, 0, ctx.team_world)
    heap = device_mod.reduce(wg, heap, buf, buf, "sum", ctx.team_world)
    doc = export_mod.chrome_trace(ctx.tracer)
    events = doc["traceEvents"]
    dev = [e for e in events if str(e.get("name", "")).startswith("device_")]
    return {
        "device_events": len(dev),
        "device_names": sorted({e["name"] for e in dev}),
        "total_events": len(events),
    }


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def run():
    ab = _fused_ab()
    for mode in ("barrier", "fused"):
        emit("device_fused_ttfd", f"mode={mode}",
             ab[mode]["ttfd_model_s"] * 1e6,
             steps=ab[mode]["ttfd_steps"],
             first_block_steps=ab[mode]["first_block_steps"],
             bitwise=ab["bitwise_identical"])
    ring = _ring_overlap()
    emit("device_ring_attention", f"npes={RING_NPES},S={RING_SEQ_MODEL}",
         ring["t_overlap_s"] * 1e6,
         blocking_us=f"{ring['t_blocking_s'] * 1e6:.1f}",
         overlap=f"{ring['overlap_ratio']:.2f}",
         numeric_err=f"{ring['numeric_max_err']:.2e}")
    fit = _cutover_fit()
    for key, co in fit["fitted_cutovers"].items():
        emit("device_cutover_fit", key, 0.0, cutover_B=co)
    tr = _trace_smoke()
    emit("device_trace", "span-coverage", 0.0,
         device_events=tr["device_events"], total=tr["total_events"])


def smoke(json_path: str = "BENCH_device.json") -> dict:
    """CI smoke: all four checks -> JSON artifact (scripts/ci.sh gates)."""
    doc = {
        "bench": "device_smoke",
        "arch": cfgbase.reduced(cfgbase.get_config(ARCH)).name,
        "fused_vs_barrier": _fused_ab(),
        "ring_attention": _ring_overlap(),
        "cutover_fit": _cutover_fit(),
        "trace": _trace_smoke(),
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    ab = doc["fused_vs_barrier"]
    emit("device_smoke", json_path, ab["fused"]["ttfd_model_s"] * 1e6,
         ttfd_improvement=f"{ab['ttfd_model_improvement']:.2f}",
         bitwise=ab["bitwise_identical"],
         ring_overlap=f"{doc['ring_attention']['overlap_ratio']:.2f}",
         device_events=doc["trace"]["device_events"])
    return doc


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", nargs="?", const="BENCH_device.json",
                    default=None, metavar="PATH",
                    help="CI smoke: fused-vs-barrier TTFD + ring overlap + "
                         "cutover fit + trace coverage -> JSON artifact")
    cli = ap.parse_args()
    if cli.smoke is not None:
        smoke(cli.smoke)
    else:
        run()
