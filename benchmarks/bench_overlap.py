"""Comm-compute overlap: the completion engine's headline number.

Two experiments, both against the blocking baseline:

1. **nbi ring allreduce** — modeled time of a ring allreduce whose per-step
   neighbor transfer is in flight while the previous chunk's tile-add
   computes (``cutover.t_ring_allreduce(overlap=True)``) vs the blocking
   schedule.  Overlap efficiency = t_blocking / t_nbi (> 1.0 whenever there
   is compute to hide — the paper's §III-F promise).

2. **write combining** — a real :class:`~repro.core.pending.CompletionQueue`
   run: many small contiguous ``put_nbi`` calls, one ``quiet``.  The flush
   coalesces them into few wire transfers; the coalescing ratio
   (ops/transfers) and the modeled flush-time gain are reported, with the
   same workload re-run under ``nbi_coalesce=False`` as the control.

``smoke(json_path)`` is the CI entry point: one small instance of each,
written to ``BENCH_overlap.json`` next to the cutover profile.
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import context, cutover, rma

NPES = 8
SIZES = tuple(1 << b for b in range(12, 25, 2))          # 4 KB .. 16 MB


def _overlap_row(nbytes, *, work_items=None, hw=None):
    """Ring allreduce where each arriving chunk feeds the next tile's
    compute (consumer tile = 4 chunks: the chunk read against resident
    weights) — the §III-F scenario the nbi ring step exists for.
    ``work_items=None`` follows ISHMEM_WORK_GROUP_SIZE."""
    from repro.tune import env as env_mod
    work_items = cutover.resolve_work_items(work_items,
                                            env_mod.tuning_from_env())
    hw = hw or cutover.HwParams()
    kw = dict(work_items=work_items, hw=hw,
              step_compute_bytes=4 * nbytes / NPES)
    tb = cutover.t_ring_allreduce(nbytes, NPES, overlap=False, **kw)
    tn = cutover.t_ring_allreduce(nbytes, NPES, overlap=True, **kw)
    return tb, tn, tb / tn


def _coalesce_run(n_puts: int, elems_per_put: int, *, coalesce: bool):
    """Issue ``n_puts`` contiguous small nbi puts + one quiet through a real
    context; returns (queue stats, modeled flush seconds)."""
    ctx, heap = context.init(npes=2, node_size=2)
    ctx.tuning = dataclasses.replace(ctx.tuning, nbi_coalesce=coalesce)
    buf = heap.malloc((n_puts * elems_per_put,), "float32")
    t0 = ctx.total_time()
    for i in range(n_puts):
        piece = rma.SymPtr("float32", buf.offset + i * elems_per_put,
                           (elems_per_put,))
        heap = rma.put_nbi(ctx, heap, piece,
                           jnp.full(elems_per_put, float(i)), 1)
    heap = rma.quiet(ctx, heap)
    assert float(heap.read(buf, 1)[-1]) == float(n_puts - 1)
    return ctx.pending.stats, ctx.total_time() - t0


def run():
    hw = cutover.HwParams()
    for wi in (1, 128, 1024):
        for nbytes in SIZES:
            tb, tn, eff = _overlap_row(nbytes, work_items=wi, hw=hw)
            emit("overlap_ring", f"wi={wi},{nbytes}B", tn * 1e6,
                 blocking_us=f"{tb * 1e6:.3f}", efficiency=f"{eff:.3f}")

    for n_puts in (16, 128):
        stats, t_co = _coalesce_run(n_puts, 128, coalesce=True)
        _, t_un = _coalesce_run(n_puts, 128, coalesce=False)
        emit("overlap_coalesce", f"puts={n_puts}x512B", t_co * 1e6,
             transfers=stats.transfers,
             ratio=f"{stats.coalescing_ratio():.1f}",
             uncoalesced_us=f"{t_un * 1e6:.3f}",
             gain=f"{t_un / t_co:.2f}")


def smoke(json_path: str = "BENCH_overlap.json") -> dict:
    """CI smoke: one overlap point + one coalescing run -> JSON artifact."""
    nbytes = 1 << 20
    tb, tn, eff = _overlap_row(nbytes)
    stats, t_co = _coalesce_run(64, 128, coalesce=True)
    _, t_un = _coalesce_run(64, 128, coalesce=False)
    doc = {
        "bench": "overlap_smoke",
        "ring_allreduce": {
            "nbytes": nbytes, "npes": NPES,
            "t_blocking_s": tb, "t_nbi_s": tn,
            "overlap_efficiency": eff,
        },
        "write_combining": {
            "puts": 64, "bytes_per_put": 512,
            "transfers": stats.transfers,
            "coalescing_ratio": stats.coalescing_ratio(),
            "t_coalesced_s": t_co, "t_uncoalesced_s": t_un,
            "flush_gain": t_un / t_co if t_co else 1.0,
        },
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("overlap_smoke", json_path, tn * 1e6,
         efficiency=f"{eff:.3f}",
         coalescing_ratio=f"{stats.coalescing_ratio():.1f}")
    return doc


if __name__ == "__main__":
    run()
