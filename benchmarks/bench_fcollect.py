"""Paper Fig. 6: fcollect_work_group time vs element count for varying
work-items and PE counts, against the host-initiated copy-engine line.
The crossover element count depends on BOTH work-items and #PEs.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import cutover


def run():
    hw = cutover.HwParams()
    for npes in (4, 8, 12):
        for wi in (256, 1024):
            for le in range(4, 21):                     # 16 .. 1M elements
                nelems = 1 << le
                nbytes = nelems * 4
                td = cutover.t_collective("fcollect", nbytes, npes,
                                          work_items=wi, path="direct", hw=hw)
                te = cutover.t_collective("fcollect", nbytes, npes,
                                          path="engine", hw=hw)
                emit("fig6_fcollect", f"pes={npes},wi={wi},{nelems}el",
                     min(td, te) * 1e6, direct_us=f"{td * 1e6:.2f}",
                     engine_us=f"{te * 1e6:.2f}",
                     winner="direct" if td <= te else "engine")
            co = cutover.collective_cutover_elems("fcollect", npes, 4,
                                                  work_items=wi, hw=hw)
            emit("fig6_cutover_point", f"pes={npes},wi={wi}", 0.0,
                 cutover_elems=min(co, 1 << 40))


if __name__ == "__main__":
    run()
