"""Cluster-frontend benchmarks: SLO scheduling, shedding, prefix affinity.

Three experiments on the fleet (``repro.serve.frontend``), all driven
through the real multi-pod machine — open-loop traffic, router, SLO
admission, parked streams, preemption, shared pool:

1. **SLO vs FCFS under overload** — the identical overloaded arrival
   schedule served twice; the SLO policy's priority pop + over-budget
   preemption must strictly beat FCFS on the *interactive* class's p99
   TTFD measured from arrival (queue time counts).  CI-gated.
2. **goodput vs offered load** — an offered-rate sweep past saturation
   with shedding armed: good throughput (requests finishing inside their
   class deadline per step) must degrade gracefully — sheds fire and the
   good rate stays near its capacity plateau instead of collapsing under
   unbounded queues.  CI-gated.
3. **prefix-affinity routing** — a shared-prefix workload routed randomly
   vs by affinity; the affinity arm must cut the cross-pod wire bytes
   (prefix blocks pulled over the host-proxy ring by wrong-pod routing).
   CI-gated.

``smoke(json_path)`` emits BENCH_fleet.json for ``scripts/ci.sh``.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import emit
from repro.configs import base as cfgbase
from repro.serve.engine import Engine
from repro.serve.frontend import (Fleet, FleetConfig, TenantSpec,
                                  TrafficEngine)

ARCH = "qwen3_4b"
SEED = 7
STEPS = 24              # open-loop arrival window (drain runs to empty)
MAXLEN = 24

#: interactive chat against a long-decode batch scan — the mix that makes
#: FCFS head-of-line blocking visible and gives preemption a victim
MIX = (TenantSpec("chat", weight=1.0, prompt_lens=(8,), max_new=(4,),
                  slo="interactive"),
       TenantSpec("scan", weight=1.0, prompt_lens=(12,), max_new=(12,),
                  slo="batch"))

#: many-samples-one-prompt tenant for the affinity experiment
PREFIX_MIX = (TenantSpec("samples", prompt_lens=(12,), max_new=(4,),
                         slo="standard", shared_prefix_prob=0.8,
                         prefix_groups=1),)

RATE_CAPACITY = 0.8
RATE_OVERLOAD = 1.2
RATE_PAST_SAT = 3.2


def _engine():
    import jax
    from repro.models import model
    cfg = cfgbase.reduced(cfgbase.get_config(ARCH))
    params = model.init_params(jax.random.key(0), cfg)
    return Engine(cfg, params, max_len=MAXLEN)


def _fleet(engine, *, admission, router="least_loaded", queue_bound=4):
    fcfg = FleetConfig(n_pods=2, prefill_per_pod=1, decode_per_pod=2,
                       num_slots=1, kv_blocks=128, block_tokens=4,
                       max_len=MAXLEN, max_new=4, stream_chunks=2,
                       admission=admission, router=router,
                       queue_bound=queue_bound, seed=SEED)
    return Fleet(fcfg, engine=engine)


def _serve(engine, tenants, rate, *, admission="slo",
           router="least_loaded", queue_bound=4, steps=STEPS):
    fleet = _fleet(engine, admission=admission, router=router,
                   queue_bound=queue_bound)
    traffic = TrafficEngine(list(tenants), rate=rate,
                            vocab=fleet.cfg.vocab_size, seed=SEED)
    t0 = time.perf_counter()
    rep = fleet.run(traffic.schedule(steps), max_steps=4000)
    rep["wall_s"] = time.perf_counter() - t0
    return rep


def slo_vs_fcfs(engine) -> dict:
    """The same overloaded schedule under FCFS and SLO admission."""
    fcfs = _serve(engine, MIX, RATE_OVERLOAD, admission="fcfs")
    slo = _serve(engine, MIX, RATE_OVERLOAD, admission="slo")
    out = {"rate": RATE_OVERLOAD}
    for name, rep in (("fcfs", fcfs), ("slo", slo)):
        ia = rep["by_class"].get("interactive", {})
        out[name] = {
            "interactive_ttfd_p50_steps": ia.get("ttfd_p50_steps", 0.0),
            "interactive_ttfd_p99_steps": ia.get("ttfd_p99_steps", 0.0),
            "interactive_goodput": ia.get("goodput", 0.0),
            "goodput": rep["goodput"],
            "preempts": rep["preempts"],
            "resumes": rep["resumes"],
            "elapsed_steps": rep["elapsed_steps"],
        }
    return out


def goodput_sweep(engine) -> dict:
    """Offered-load sweep through and past saturation, SLO + shed armed."""
    points = []
    for rate in (RATE_CAPACITY, RATE_OVERLOAD * 4 / 3, RATE_PAST_SAT):
        rep = _serve(engine, MIX, rate)
        points.append({
            "rate": rate,
            "offered": rep["offered"],
            "good": rep["good"],
            "shed": rep["shed"],
            "goodput": rep["goodput"],
            "goodput_per_step": rep["goodput_per_step"],
            "preempts": rep["preempts"],
        })
    return {"points": points}


def affinity_savings(engine) -> dict:
    """Random vs prefix-affinity routing on a shared-prefix workload."""
    out = {}
    for router in ("random", "affinity"):
        rep = _serve(engine, PREFIX_MIX, 0.6, router=router)
        out[router] = {
            "bytes_cross_pod": rep["wire"]["bytes_cross_pod"],
            "bytes_wire_saved": rep["wire"]["bytes_wire_saved"],
            "proxy_delivered": (rep.get("proxy") or {}).get("delivered", 0),
            "affinity_hits": rep["router"]["affinity_hits"],
            "completed": rep["completed"],
        }
    return out


def run():
    engine = _engine()
    ab = slo_vs_fcfs(engine)
    for arm in ("fcfs", "slo"):
        emit("fleet_slo_ab", f"admission={arm},rate={ab['rate']}",
             0.0, interactive_p99_ttfd_steps=ab[arm][
                 "interactive_ttfd_p99_steps"],
             goodput=f"{ab[arm]['goodput']:.2f}",
             preempts=ab[arm]["preempts"])
    sweep = goodput_sweep(engine)
    for p in sweep["points"]:
        emit("fleet_goodput", f"rate={p['rate']:.2f}", 0.0,
             good_per_step=f"{p['goodput_per_step']:.3f}",
             shed=p["shed"], goodput=f"{p['goodput']:.2f}")
    aff = affinity_savings(engine)
    for router, a in aff.items():
        emit("fleet_affinity", f"router={router}", 0.0,
             cross_pod_bytes=a["bytes_cross_pod"],
             wire_saved=a["bytes_wire_saved"])


def smoke(json_path: str = "BENCH_fleet.json") -> dict:
    """CI smoke: all three experiments -> JSON artifact."""
    engine = _engine()
    doc = {
        "bench": "fleet_smoke",
        "arch": cfgbase.reduced(cfgbase.get_config(ARCH)).name,
        "slo_vs_fcfs": slo_vs_fcfs(engine),
        "goodput": goodput_sweep(engine),
        "affinity": affinity_savings(engine),
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    ab = doc["slo_vs_fcfs"]
    emit("fleet_smoke", json_path, 0.0,
         fcfs_p99=ab["fcfs"]["interactive_ttfd_p99_steps"],
         slo_p99=ab["slo"]["interactive_ttfd_p99_steps"],
         shed=doc["goodput"]["points"][-1]["shed"],
         affinity_cross_pod=doc["affinity"]["affinity"]["bytes_cross_pod"])
    return doc


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", nargs="?", const="BENCH_fleet.json",
                    default=None, metavar="PATH",
                    help="CI smoke: SLO-vs-FCFS + goodput sweep + affinity "
                         "savings -> JSON artifact")
    cli = ap.parse_args()
    if cli.smoke is not None:
        smoke(cli.smoke)
    else:
        run()
