"""Measured (interpret-mode, CPU) kernel micro-benchmarks: relative trends of
the device-initiated ring collectives and the work-group copy tile sweep.
Absolute numbers are CPU-interpreter time, not TPU time — the TPU projection
is the modeled column in the other benches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_of, emit


def run():
    from jax.sharding import PartitionSpec as P
    from repro.kernels import ops

    # local work-group copy: (work-item x size) sweep — several sizes per
    # work group so the wall-clock estimator has spread to fit a line through
    dst = jnp.zeros(1 << 18, jnp.float32)
    for wi in (1, 4, 16):
        for lg in (12, 14, 16):
            src = jnp.arange(1 << lg, dtype=jnp.float32)
            f = lambda: ops.wg_copy_local(dst, src, 0, work_items=wi) \
                .block_until_ready()
            t = best_of(f, trials=5,
                        record=("put", src.size * 4, "direct", "local", wi))
            emit("kern_wg_copy", f"wi={wi},{(1 << lg) * 4}B", t * 1e6,
                 measured="cpu-interp")

    # reduce tile: block sweep
    rows = jax.random.normal(jax.random.key(0), (8, 4096))
    for blk in (128, 512, 2048):
        f = lambda: ops.reduce_tile(rows, "sum", block=blk) \
            .block_until_ready()
        t = best_of(f, trials=5,
                    record=("reduce", rows.size * 4, "direct", "local", blk))
        emit("kern_reduce_tile", f"block={blk}", t * 1e6,
             measured="cpu-interp")

    # ring collectives across 8 simulated PEs
    ndev = len(jax.devices())
    if ndev >= 8:
        mesh = jax.make_mesh((8,), ("x",))
        for chunk in (256, 2048):
            x = jax.random.normal(jax.random.key(1), (8, chunk))
            f = jax.jit(jax.shard_map(
                lambda v: ops.ring_allgather(v[0], axis_name="x",
                                             npes=8)[None],
                mesh=mesh, in_specs=P("x", None), out_specs=P("x", None, None),
                check_vma=False))
            try:
                f(x).block_until_ready()
            except (TypeError, NotImplementedError):
                # jax 0.4.x pallas interpret-mode remote-DMA drift — same
                # inventory as tests/_drift.py (ROADMAP "Open items")
                emit("kern_ring_fcollect", f"pes=8,{chunk * 4}B", 0.0,
                     note="skipped(jax-drift)")
                continue
            t = best_of(lambda: f(x).block_until_ready(), trials=3,
                        record=("fcollect", chunk * 4, "direct", "ici", 8))
            emit("kern_ring_fcollect", f"pes=8,{chunk * 4}B", t * 1e6,
                 measured="cpu-interp")
    else:
        emit("kern_ring_fcollect", "skipped", 0.0,
             note=f"needs 8 devices, have {ndev}")


if __name__ == "__main__":
    run()
