"""Paged KV-cache migration: bandwidth, TTFD, and migration-under-decode.

Three experiments on the disaggregated serving data plane
(``repro.serve.kvpool`` / ``kvxfer``):

1. **migration bandwidth** — a real protocol run (stage, ``put_signal_nbi``
   streaming, signal-gated admission) over a sweep of prompt lengths; the
   modeled wire time comes from the flush-time (coalesced) transfer records
   in the context ledger, and the wall-clock of the whole protocol machine
   feeds the MEASURED tuning sink.
2. **time-to-first-decode-token** — the decode-side admission latency: the
   migration wire time plus one decode step of the slot bank, vs the decode
   step alone (the non-disagg floor).
3. **overlap** — steady-state continuous batching: every ``decode_len``
   steps a slot turns over, so each decode step carries
   ``slots/decode_len`` admissions' worth of migration traffic.
   stop-the-world pays ``t_dec + t_mig`` per step; the nbi schedule pays
   ``max(t_dec, t_mig)`` plus the admission quiet — the same completion
   engine pricing every other overlap number in this repo uses.

``smoke(json_path)`` is the CI entry point (BENCH_kvxfer.json): asserts in
scripts/ci.sh cover overlap >= 1.2 at MB-scale KV and an active coalescing
ratio, and the per-block cutover telemetry is fitted into a TuningTable to
prove the serving traffic reaches the tuner.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import best_of, emit
from repro.configs import base as cfgbase
from repro.core import context, cutover
from repro.models import kvcache
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator

ARCH = "qwen3_4b"
PROMPTS = (64, 256, 1024)            # tokens; ~KB..MB-scale KV
BLOCK_TOKENS = 16
DECODE_LEN = 16                      # new tokens per request (churn rate)
SLOTS = 8                            # decode slot bank


def _cfg():
    return cfgbase.reduced(cfgbase.get_config(ARCH))


def _filled_cache(cfg, width):
    """Deterministic synthetic prefill result (no model run: the protocol
    machine only moves bytes)."""
    cache = kvcache.init_cache(cfg, 1, width)
    leaves, treedef = jax.tree.flatten(cache)
    filled = [
        (jnp.arange(l.size, dtype=jnp.float32).reshape(l.shape) % 97 + i)
        .astype(l.dtype) for i, l in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, filled)


def _protocol_run(prompt_tokens: int, *, block_tokens: int = BLOCK_TOKENS):
    """One full stage->migrate->admit cycle on a fresh context.

    Returns (report, t_wire_s, pending_stats, ctx): t_wire_s sums the
    flush-time transfer records (the coalesced wire cost), excluding the
    zero-cost queue markers and the per-block advisory telemetry.
    """
    cfg = _cfg()
    ctx, heap = context.init(npes=2, node_size=2)
    pool = KVPool.create(heap, cfg, prompt_tokens,
                         num_blocks=2 * (prompt_tokens // block_tokens) + 2,
                         max_slots=1, block_tokens=block_tokens)
    mig = KVMigrator(ctx, pool)
    cache = _filled_cache(cfg, prompt_tokens)
    heap, ids = mig.stage(heap, 0, cache, prompt_len=prompt_tokens, src_pe=0)
    mark = len(ctx.ledger)
    heap, rep = mig.migrate(heap, 0, src_pe=0, dst_pe=1, slot=0,
                            prompt_len=prompt_tokens, first_token=1)
    heap, hdr = mig.try_admit(heap, 0, 1, rep.expected_signal)
    assert hdr is not None and hdr["n_blocks"] == len(ids)
    wire_ops = ("put_nbi", "signal", "quiet")
    t_wire = sum(r.t_sec for r in ctx.ledger[mark:] if r.op in wire_ops)
    return rep, t_wire, ctx.pending.stats, ctx


def _param_bytes(cfg) -> int:
    """Rough decode-step weight traffic (the HBM-bound floor)."""
    d = cfg.d_model
    unit, reps = cfgbase.repeat_unit(cfg)
    per_layer = 4 * d * d + (3 * d * cfg.d_ff if cfg.d_ff else 0)
    n = len(unit) * reps * per_layer
    n += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return n * 4


def _kv_bytes_per_token(cfg, width) -> int:
    from repro.serve.kvpool import build_layout
    lay = build_layout(cfg, width, block_tokens=BLOCK_TOKENS)
    per_tok = sum(p.words_per_token for p in lay.paged)
    return per_tok * jnp.dtype(lay.kv_dtype).itemsize


def _decode_step_seconds(cfg, batch, pos, hw) -> float:
    """One slot-bank decode step: stream the weights + the resident KV."""
    nbytes = _param_bytes(cfg) + batch * pos * _kv_bytes_per_token(cfg, pos)
    return 2 * hw.alpha_direct + nbytes / hw.hbm_bw


def _overlap_row(prompt_tokens: int, *, slots: int = SLOTS,
                 decode_len: int = DECODE_LEN, hw=None, protocol=None):
    """Steady-state migration-under-decode.  Returns
    ``(report, t_wire, t_dec, t_mig_per_step, t_stop_world, t_overlapped)``;
    ``protocol=(report, t_wire)`` reuses an already-run protocol cycle."""
    hw = hw or cutover.HwParams()
    cfg = _cfg()
    if protocol is None:
        rep, t_wire, _, _ = _protocol_run(prompt_tokens)
    else:
        rep, t_wire = protocol
    t_dec = _decode_step_seconds(cfg, slots, prompt_tokens, hw)
    admissions_per_step = slots / decode_len
    t_mig = admissions_per_step * t_wire
    stw = t_dec + t_mig
    ovl = max(t_dec, t_mig) + 2 * hw.alpha_direct    # admission quiet
    return rep, t_wire, t_dec, t_mig, stw, ovl


def run():
    for prompt in PROMPTS:
        rep, t_wire, stats, _ = _protocol_run(prompt)
        bw = rep.bytes_total / t_wire if t_wire else 0.0
        # wall-clock of the whole protocol machine (context init + pack +
        # flush + admission) — reporting only, never record= into MEASURED:
        # it is not a transfer sample and would skew the engine-profile fit
        wall = best_of(lambda: _protocol_run(prompt), trials=3)
        emit("kvxfer_bw", f"prompt={prompt}", t_wire * 1e6,
             bytes=rep.bytes_total, runs=rep.n_runs, blocks=rep.n_blocks,
             modeled_GBs=f"{bw / 1e9:.2f}",
             coalescing=f"{stats.coalescing_ratio():.2f}",
             wall_ms=f"{wall * 1e3:.1f}")

    hw = cutover.HwParams()
    cfg = _cfg()
    for prompt in PROMPTS:
        rep, t_wire, _, _ = _protocol_run(prompt)
        t_dec = _decode_step_seconds(cfg, SLOTS, prompt, hw)
        emit("kvxfer_ttfd", f"prompt={prompt}", (t_wire + t_dec) * 1e6,
             decode_floor_us=f"{t_dec * 1e6:.2f}",
             migration_us=f"{t_wire * 1e6:.2f}")
        _, _, t_dec, t_mig, stw, ovl = _overlap_row(prompt,
                                                    protocol=(rep, t_wire))
        emit("kvxfer_overlap", f"prompt={prompt},slots={SLOTS}",
             stw * 1e6, decode_us=f"{t_dec * 1e6:.2f}",
             mig_us=f"{t_mig * 1e6:.2f}", overlap=f"{stw / ovl:.2f}")


def measured() -> list:
    """Wall-clock measurement mode (``benchmarks.run --measured``).

    Times the re-runnable wire cycle — migrate (deferred ``put_signal_nbi``
    streaming) + signal-gated admission — from an already-staged immutable
    heap snapshot, at several KV sizes, and records the trimmed median into
    the MEASURED sink's ``"wallclock"`` stream.  Staging and context init
    stay OUTSIDE the timed region (the ``run()`` caveat about whole-protocol
    wall clock), so the sample is the transfer machine itself and is honest
    input for an engine-path profile fit."""
    from benchmarks import common
    rows = []
    for prompt in PROMPTS:
        cfg = _cfg()
        ctx, heap = context.init(npes=2, node_size=2)
        pool = KVPool.create(heap, cfg, prompt,
                             num_blocks=2 * (prompt // BLOCK_TOKENS) + 2,
                             max_slots=1, block_tokens=BLOCK_TOKENS)
        mig = KVMigrator(ctx, pool)
        cache = _filled_cache(cfg, prompt)
        heap, ids = mig.stage(heap, 0, cache, prompt_len=prompt, src_pe=0)

        def cycle(heap=heap, mig=mig, prompt=prompt):
            h, rep = mig.migrate(heap, 0, src_pe=0, dst_pe=1, slot=0,
                                 prompt_len=prompt, first_token=1)
            h, hdr = mig.try_admit(h, 0, 1, rep.expected_signal)
            assert hdr is not None
            return rep

        rep = cycle()
        details = {}
        best_of(cycle, discard=1, details=details,
                record=("kvxfer_wire", rep.bytes_total, "engine",
                        ctx.tier(0, 1), mig.work_items))
        emit("kvxfer_measured", f"prompt={prompt}",
             details["min"] * 1e6,
             tmed_us=f"{details['tmed'] * 1e6:.3f}",
             bytes=rep.bytes_total, blocks=rep.n_blocks,
             trials=details["trials"])
        rows.append({"prompt": prompt, "bytes": rep.bytes_total,
                     "min_s": details["min"], "tmed_s": details["tmed"]})
    assert common.MEASURED.nsamples("wallclock") >= len(PROMPTS)
    return rows


def smoke(json_path: str = "BENCH_kvxfer.json") -> dict:
    """CI smoke: MB-scale migration + steady-state overlap -> JSON."""
    prompt = 1024                     # ~MB-scale paged KV per request
    rep, t_wire, stats, ctx = _protocol_run(prompt)
    _, _, t_dec, t_mig, stw, ovl = _overlap_row(prompt,
                                                protocol=(rep, t_wire))
    ratio = stw / ovl
    # per-block cutover telemetry -> fitted tuning table (the serving
    # traffic's path into the autotuner)
    blk = [k for k in ctx.telemetry.buckets if k[0] == "kvxfer_block"]
    tbl = ctx.fit_tuning_table(arm=False)
    doc = {
        "bench": "kvxfer_smoke",
        "arch": _cfg().name,
        "migration": {
            "prompt_tokens": prompt,
            "bytes": rep.bytes_total,
            "blocks": rep.n_blocks,
            "runs": rep.n_runs,
            "t_wire_s": t_wire,
            "bw_GBs": rep.bytes_total / t_wire / 1e9 if t_wire else 0.0,
            "coalescing_ratio": stats.coalescing_ratio(),
        },
        "ttfd": {
            "decode_floor_s": t_dec,
            "ttfd_s": t_dec + t_wire,
        },
        "overlap": {
            "slots": SLOTS,
            "decode_len": DECODE_LEN,
            "t_decode_step_s": t_dec,
            "t_migration_per_step_s": t_mig,
            "stop_the_world_s": stw,
            "overlapped_s": ovl,
            "overlap_ratio": ratio,
        },
        "telemetry": {
            "kvxfer_block_buckets": len(blk),
            "fitted_profiles": len(tbl.profiles),
        },
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("kvxfer_smoke", json_path, t_wire * 1e6,
         overlap=f"{ratio:.2f}",
         coalescing_ratio=f"{stats.coalescing_ratio():.2f}")
    return doc


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", nargs="?", const="BENCH_kvxfer.json",
                    default=None, metavar="PATH",
                    help="CI smoke: one MB-scale migration + overlap point "
                         "-> JSON artifact")
    ap.add_argument("--measured", action="store_true",
                    help="wall-clock measurement mode: time the re-runnable "
                         "wire cycle per KV size, record trimmed medians "
                         "into the wallclock telemetry stream")
    cli = ap.parse_args()
    if cli.smoke is not None:
        smoke(cli.smoke)
    elif cli.measured:
        print("bench,config,us_per_call,derived")
        measured()
    else:
        run()
